"""Local stand-in for the CI ruff floor's dead-code rules.

CI gates ``ruff check src tests benchmarks`` with F401 (unused
import), F811 (redefinition), and F841 (unused local) selected
(pyproject.toml). The dev image does not ship ruff, so this AST
checker approximates exactly those three rules for the pre-push loop:

    python tools/lint_floor.py src tests benchmarks

It is intentionally conservative (no cross-module analysis, no type
comments): a clean run here does not guarantee a clean ruff run, but
every finding here is one ruff would also flag. ``# noqa`` comments
(bare or listing the code) suppress a line's findings, matching ruff.
``__init__.py`` files skip F401 — their imports are re-exports.
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

_NOQA = re.compile(r"#\s*noqa(?::\s*(?P<codes>[A-Z0-9, ]+))?", re.I)


def _noqa_lines(src: str) -> dict:
    """{lineno: set of silenced codes (empty set = all)}."""
    out = {}
    for i, line in enumerate(src.splitlines(), 1):
        m = _NOQA.search(line)
        if m:
            codes = m.group("codes")
            out[i] = ({c.strip().upper() for c in codes.split(",")}
                      if codes else set())
    return out


class _Scope:
    def __init__(self, node, is_function):
        self.node = node
        self.is_function = is_function
        self.imports = {}       # name -> (lineno, code-source)
        self.assigns = {}       # name -> lineno of last simple assign
        self.defs = {}          # name -> lineno of last def/class/import
        self.used: set = set()


def _names_used(node) -> set:
    used = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load):
            used.add(n.id)
        elif isinstance(n, ast.Attribute):
            root = n
            while isinstance(root, ast.Attribute):
                root = root.value
            if isinstance(root, ast.Name):
                used.add(root.id)
        elif isinstance(n, ast.Constant) and isinstance(n.value, str):
            pass
    return used


def _all_exports(tree) -> set:
    """Names listed in a module-level ``__all__`` literal."""
    out = set()
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == "__all__" \
                        and isinstance(node.value, (ast.List, ast.Tuple)):
                    for elt in node.value.elts:
                        if isinstance(elt, ast.Constant) \
                                and isinstance(elt.value, str):
                            out.add(elt.value)
    return out


def _import_names(node):
    """(bound-name, lineno) pairs for an import statement."""
    for alias in node.names:
        if alias.name == "*":
            continue
        name = alias.asname or alias.name.split(".")[0]
        yield name, node.lineno


def _check_f841(fn, findings, path):
    """Unused simple locals in one function body (skips _-prefixed,
    augmented, unpacked, for-targets and closure cells — the
    conservative pyflakes core)."""
    assigned = {}        # name -> lineno (simple assigns only)
    for node in ast.walk(fn):
        if node is fn:
            continue
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef, ast.Lambda)):
            # nested scopes are walked separately; their loads count as
            # uses of the outer name (closures), handled below
            continue
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            name = node.targets[0].id
            if not name.startswith("_"):
                assigned[name] = node.lineno
    if not assigned:
        return
    used = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and not isinstance(node.ctx,
                                                         ast.Store):
            used.add(node.id)
        elif isinstance(node, ast.Attribute):
            root = node
            while isinstance(root, ast.Attribute):
                root = root.value
            if isinstance(root, ast.Name):
                used.add(root.id)
        elif isinstance(node, (ast.AugAssign,)) \
                and isinstance(node.target, ast.Name):
            used.add(node.target.id)
        elif isinstance(node, ast.Global):
            used.update(node.names)
        elif isinstance(node, ast.Nonlocal):
            used.update(node.names)
    for name, lineno in sorted(assigned.items(), key=lambda kv: kv[1]):
        if name not in used:
            findings.append((path, lineno, "F841",
                             f"local variable `{name}` is assigned to "
                             f"but never used"))


def check_file(path: Path) -> list:
    src = path.read_text()
    try:
        tree = ast.parse(src, filename=str(path))
    except SyntaxError as e:
        return [(path, e.lineno or 0, "E999", f"syntax error: {e.msg}")]
    findings = []
    noqa = _noqa_lines(src)

    # ---- F401: module-level imports never referenced
    if path.name != "__init__.py":
        imported = {}
        for node in tree.body:
            if isinstance(node, (ast.Import, ast.ImportFrom)):
                if isinstance(node, ast.ImportFrom) \
                        and node.module == "__future__":
                    continue
                for name, lineno in _import_names(node):
                    imported[name] = lineno
        used = _names_used(tree) | _all_exports(tree)
        for name, lineno in sorted(imported.items(),
                                   key=lambda kv: kv[1]):
            if name not in used:
                findings.append((path, lineno, "F401",
                                 f"`{name}` imported but unused"))

    # ---- F811: redefinition of an unused def/class at the same scope
    def scan_defs(body, where):
        seen = {}
        for node in body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                name = node.name
                if name in seen and not any(
                        isinstance(d, ast.Name) and d.id in (
                            "overload", "property", "setter")
                        for d in getattr(node, "decorator_list", [])):
                    deco_ok = any(
                        isinstance(d, ast.Attribute)
                        and d.attr in ("setter", "getter", "deleter",
                                       "register")
                        for d in node.decorator_list)
                    if not deco_ok:
                        findings.append(
                            (path, node.lineno, "F811",
                             f"redefinition of `{name}` (from line "
                             f"{seen[name]}) in {where}"))
                seen[name] = node.lineno
                if isinstance(node, ast.ClassDef):
                    scan_defs(node.body, f"class {name}")
    scan_defs(tree.body, "module")

    # ---- F841: unused locals per function
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            _check_f841(node, findings, path)

    # ---- apply noqa suppression
    kept = []
    for path_, lineno, code, msg in findings:
        codes = noqa.get(lineno)
        if codes is not None and (not codes or code in codes):
            continue
        kept.append((path_, lineno, code, msg))
    return kept


def main(argv) -> int:
    roots = [Path(a) for a in (argv or ["src", "tests", "benchmarks"])]
    files = []
    for r in roots:
        files.extend(sorted(r.rglob("*.py")) if r.is_dir() else [r])
    findings = []
    for f in files:
        findings.extend(check_file(f))
    for path, lineno, code, msg in findings:
        print(f"{path}:{lineno}: {code} {msg}")
    print(f"{len(findings)} finding(s) in {len(files)} files")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
