"""Batched serving example: prefill + greedy decode with KV caches on a
reduced assigned architecture (the same step functions the pod dry-run
lowers at decode_32k / long_500k).

  PYTHONPATH=src python examples/serve_decode.py --arch gemma3-27b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, make_reduced
from repro.launch.serve import generate
from repro.models import SplitModel
from repro.models.frontends import synth_frontend_embeds


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-27b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = make_reduced(get_config(args.arch))
    model = SplitModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    prefix = (synth_frontend_embeds(cfg, key, args.batch)
              if cfg.frontend else None)

    t0 = time.time()
    out = generate(cfg, params, tokens, steps=args.gen, prefix=prefix)
    dt = time.time() - t0
    print(f"arch={args.arch} (reduced) batch={args.batch}")
    print("first sequences:", out[:2].tolist())
    print(f"{args.batch * args.gen} tokens in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s on CPU)")


if __name__ == "__main__":
    main()
