"""End-to-end paper reproduction driver: FedAvg vs SFL vs S²FL on
non-IID synthetic CIFAR with ResNet8, a few hundred rounds — the Table 2 /
Figure 4 experiment at CPU scale.

  PYTHONPATH=src python examples/paper_repro.py [--rounds 100] [--alpha 0.3]
"""
import argparse

from repro.configs import get_config
from repro.core.engine import EngineConfig, S2FLEngine
from repro.data.partition import federate
from repro.data.synthetic import make_image_dataset
from repro.models import SplitModel


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--alpha", type=float, default=0.3)
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--local-steps", type=int, default=2)
    args = ap.parse_args()

    data = make_image_dataset(3000, seed=0)
    test = make_image_dataset(600, seed=99)
    fed = federate(data, args.clients, alpha=args.alpha, seed=0)
    model = SplitModel(get_config("resnet8"))

    results = {}
    for mode in ("fedavg", "sfl", "s2fl"):
        ecfg = EngineConfig(mode=mode, rounds=args.rounds,
                            clients_per_round=5, batch_size=32,
                            local_steps=args.local_steps,
                            group_size=2, lr=0.05, seed=0)
        eng = S2FLEngine(model, fed, ecfg)
        eng.run(eval_data=test, eval_every=max(args.rounds // 5, 1))
        res = eng.evaluate(test)
        results[mode] = (res["acc"], eng.clock)
        print(f"{mode:7s} acc={res['acc']:.4f} loss={res['loss']:.4f} "
              f"sim_clock={eng.clock:.0f}s")
    gain = results["s2fl"][0] - results["sfl"][0]
    print(f"\nS²FL - SFL accuracy gain: {gain:+.4f} "
          f"(paper: up to +16.5% on CIFAR-100/VGG16)")


if __name__ == "__main__":
    main()
