"""Quickstart: one S²FL round, spelled out with the public API.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core.balance import greedy_groups, label_histogram
from repro.core.engine import EngineConfig, S2FLEngine
from repro.core.split import default_plan
from repro.data.partition import federate
from repro.data.synthetic import make_image_dataset
from repro.models import SplitModel

# 1. a model the paper used, as a sequential unit stack
model = SplitModel(get_config("resnet8"))
plan = default_plan(model.n_units, k=3)
print(f"ResNet8: {model.n_units} units, split points {plan.split_points}")

# 2. non-IID federated data (Dirichlet alpha = 0.3, 10 devices)
data = make_image_dataset(1500, seed=0)
fed = federate(data, 10, alpha=0.3, seed=0)
hists = [label_histogram(fed[c]["y"], 10) for c in sorted(fed)]
print("per-device label histograms (first 3):")
for h in hists[:3]:
    print("  ", h.astype(int))

# 3. the data-balance mechanism groups complementary devices (Eq. 2)
groups = greedy_groups(hists, group_size=2)
print("balance groups:", groups)

# 4. run five S²FL rounds (sliding split + balance + Alg. 1 aggregation)
engine = S2FLEngine(model, fed, EngineConfig(
    mode="s2fl", rounds=5, clients_per_round=6, batch_size=16,
    group_size=2, lr=0.05))
test = make_image_dataset(300, seed=9)
print("initial:", engine.evaluate(test))
engine.run()
print("after 5 rounds:", engine.evaluate(test))
print(f"simulated wall clock: {engine.clock:.1f}s, "
      f"comm: {engine.comm:.3e} bytes")
