"""Split-federated LM training: S²FL over a reduced assigned architecture
on domain-skewed synthetic token data — shows the paper's mechanism is
model-agnostic (the 'label' driving Eq.-2 balance is the domain id).

  PYTHONPATH=src python examples/federated_lm.py --arch internlm2-1.8b
"""
import argparse

from repro.configs import get_config, make_reduced
from repro.core.engine import EngineConfig, S2FLEngine
from repro.data.partition import federate
from repro.data.synthetic import make_lm_dataset
from repro.models import SplitModel


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=48)
    args = ap.parse_args()

    cfg = make_reduced(get_config(args.arch))
    vocab = min(cfg.vocab_size, 256)
    train = make_lm_dataset(800, seq_len=args.seq_len, vocab=vocab, seed=0)
    test = make_lm_dataset(200, seq_len=args.seq_len, vocab=vocab, seed=9)
    fed = federate(train, 8, alpha=0.3, seed=0)

    model = SplitModel(cfg)
    eng = S2FLEngine(model, fed, EngineConfig(
        mode="s2fl", rounds=args.rounds, clients_per_round=4,
        batch_size=16, group_size=2, lr=0.05))
    print("initial:", eng.evaluate(test))
    eng.run(eval_data=test, eval_every=max(args.rounds // 4, 1),
            verbose=True)
    print("final:", eng.evaluate(test))
    print(f"split plan: {eng.plan.split_points} over {cfg.n_layers} blocks")


if __name__ == "__main__":
    main()
