"""The batched cohort compression path (kernels/comm_fused +
comm/fused.py + the channel's *_cohort methods + the engine's fused
local step).

The contract under test is the one comm/fused.py documents: wire bytes
BIT-equal to the sequential per-tensor path (so meters, Eq.-1 clocks and
recorder counters are identical), delivered tensors and residuals within
1e-6 (one fused XLA program may contract multiply-adds differently),
the error-feedback residual dict mutated with sequential-identical
semantics, and rand-k's per-call counter stream advanced one draw per
tensor in sequential transfer order (so checkpoints replay). Edge
shapes ride along: 1-element tensors, tensors smaller than the int8
GROUP, and frac=1.0 sparsifiers (k == n)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.comm import fused
from repro.comm.channel import CommChannel
from repro.comm.codecs import RandomKCodec, TopKCodec, get_codec
from repro.kernels.comm_fused import (fused_cast_roundtrip,
                                      fused_int8_roundtrip,
                                      fused_sparse_roundtrip,
                                      int8_group_geometry)
from repro.kernels.comm_fused.kernel import (int8_roundtrip_pallas,
                                             sparse_combine_pallas)
from repro.kernels.comm_fused.ref import (int8_roundtrip_ref,
                                          sparse_combine_ref)
from repro.kernels.int8_quant.ops import GROUP
from repro.kernels.int8_quant.ref import (int8_dequantize_ref,
                                          int8_quantize_ref)

KEY = jax.random.PRNGKey(11)


# ---------------------------------------------------------------------------
# fused kernels vs their jnp oracles (interpret-mode Pallas on CPU)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("r,g", [(1, 1), (3, 16), (37, 256), (300, 64)])
def test_int8_roundtrip_kernel_matches_ref_and_composed_pair(r, g):
    x = jax.random.normal(jax.random.fold_in(KEY, r * g), (r, g)) * 3.0
    out_k = int8_roundtrip_pallas(x, interpret=True)
    out_r = int8_roundtrip_ref(x)
    # interpret-mode Pallas may contract the dequantize multiply-add
    # differently than jnp — the path contract is ≤1e-6, not bit-exact
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=1e-6, rtol=1e-6)
    # the single fused kernel == the quantize/dequantize pair composed
    q, scale, zp = int8_quantize_ref(x)
    np.testing.assert_allclose(np.asarray(out_k),
                               np.asarray(int8_dequantize_ref(q, scale,
                                                              zp)),
                               atol=1e-6, rtol=1e-6)


@pytest.mark.parametrize("d,n", [(1, 8), (5, 33), (130, 17)])
def test_sparse_combine_kernel_matches_ref(d, n):
    y = jax.random.normal(jax.random.fold_in(KEY, d * n), (d, n))
    mask = (jax.random.uniform(jax.random.fold_in(KEY, d + n), (d, n))
            < 0.3).astype(jnp.float32)
    for scale in (1.0, 4.0):
        out_k, res_k = sparse_combine_pallas(y, mask, scale,
                                             interpret=True)
        out_r, res_r = sparse_combine_ref(y, mask, scale)
        np.testing.assert_array_equal(np.asarray(out_k),
                                      np.asarray(out_r))
        np.testing.assert_array_equal(np.asarray(res_k),
                                      np.asarray(res_r))
        # delivered + residual telescopes back to y where mask selects
        # with scale 1
        if scale == 1.0:
            np.testing.assert_allclose(np.asarray(out_k + res_k),
                                       np.asarray(y), atol=1e-6)


# ---------------------------------------------------------------------------
# fused ops vs the sequential per-tensor codecs
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("d,n", [(1, 1), (3, 7), (4, 300), (2, 1000)])
def test_fused_ops_match_sequential_codecs(d, n):
    x = jax.random.normal(jax.random.fold_in(KEY, 7 * d + n), (d, n))
    int8 = get_codec("int8")
    seq = jnp.stack([int8.roundtrip(x[i])[0] for i in range(d)])
    out, _ = fused_int8_roundtrip(x, None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(seq),
                               atol=1e-6)

    frac = 0.25
    k = max(1, int(np.ceil(frac * n)))
    topk = TopKCodec(frac=frac)
    seq = jnp.stack([topk.roundtrip(x[i])[0] for i in range(d)])
    out, _ = fused_sparse_roundtrip(x, None, k=k, scale=1.0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(seq))

    bf16 = get_codec("bf16")
    seq = jnp.stack([bf16.roundtrip(x[i])[0] for i in range(d)])
    out, _ = fused_cast_roundtrip(x, None, wire_dtype=jnp.bfloat16)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(seq))


def test_fused_ef_residual_is_the_sequential_dual():
    d, n = 3, 400
    x = jax.random.normal(KEY, (d, n))
    r = jax.random.normal(jax.random.fold_in(KEY, 1), (d, n)) * 0.1
    out, new_r = fused_int8_roundtrip(x, r)
    y = x + r
    int8 = get_codec("int8")
    seq = jnp.stack([int8.roundtrip(y[i])[0] for i in range(d)])
    np.testing.assert_allclose(np.asarray(out), np.asarray(seq),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(new_r), np.asarray(y - seq),
                               atol=1e-6)


def test_int8_group_geometry_matches_metered_bytes():
    int8 = get_codec("int8")
    for n in (1, 7, 255, 256, 257, 1000):
        x = jax.random.normal(jax.random.fold_in(KEY, n), (n,))
        _, nbytes = int8.roundtrip(x)
        g, rows = int8_group_geometry(n)
        assert nbytes == rows * g * 1.0 + rows * 8.0
        assert fused.payload_bytes(int8, n) == nbytes


# ---------------------------------------------------------------------------
# codec edge shapes (sequential path — regression floor for the fused
# equivalence property below)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["fp32", "bf16", "int8", "topk",
                                  "randk"])
@pytest.mark.parametrize("shape", [(1,), (3,), (GROUP - 1,),
                                   (2, GROUP + 5)])
def test_codec_roundtrip_edge_shapes(name, shape):
    codec = get_codec(name, topk_frac=0.5)
    x = jax.random.normal(jax.random.fold_in(KEY, hash(shape) % 997),
                          shape)
    out, nbytes = codec.roundtrip(x)
    assert out.shape == x.shape and out.dtype == x.dtype
    assert nbytes > 0
    # the fused path's analytic accounting is bit-equal to the bytes
    # the sequential encode metered from the materialized payload
    assert fused.payload_bytes(codec, int(np.prod(shape))) == nbytes


def test_topk_frac_one_is_lossless():
    codec = TopKCodec(frac=1.0)
    x = jax.random.normal(KEY, (4, 37))
    out, nbytes = codec.roundtrip(x)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))
    assert nbytes == x.size * 8.0 + 4.0
    # and the fused dual delivers the same
    f, _ = fused_sparse_roundtrip(x.reshape(1, -1), None, k=x.size,
                                  scale=1.0)
    np.testing.assert_array_equal(np.asarray(f).reshape(x.shape),
                                  np.asarray(x))


# ---------------------------------------------------------------------------
# rand-k replayable state
# ---------------------------------------------------------------------------
def test_randk_state_export_replays_draw_stream():
    c = RandomKCodec(frac=0.3, seed=9)
    c.draw_indices(100, 30)
    snap = c.state()
    a = [c.draw_indices(100, 30) for _ in range(3)]
    c.set_state(snap)
    b = [c.draw_indices(100, 30) for _ in range(3)]
    for u, v in zip(a, b):
        np.testing.assert_array_equal(u, v)
    c.reset()
    assert c._calls == 0
    # a fresh codec from the same seed now produces the same stream
    np.testing.assert_array_equal(c.draw_indices(50, 10),
                                  RandomKCodec(frac=0.3,
                                               seed=9).draw_indices(50,
                                                                    10))


def test_channel_codec_state_roundtrip():
    ch = CommChannel("randk", topk_frac=0.2)
    x = jax.random.normal(KEY, (4, 64))
    ch.uplink_features(0, x)
    ch.downlink_grads(0, x)
    snap = ch.export_codec_state()
    a = ch.uplink_features(1, x)
    ch2 = CommChannel("randk", topk_frac=0.2)
    ch2.restore_codec_state(snap)
    b = ch2.uplink_features(1, x)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert snap["feature"]["calls"] == 1 and snap["grad"]["calls"] == 1


# ---------------------------------------------------------------------------
# cohort channel == sequential channel (the tentpole property)
# ---------------------------------------------------------------------------
def _equiv_case(name, ef, shapes, rounds=2):
    seq = CommChannel(name, error_feedback=ef, topk_frac=0.3)
    coh = CommChannel(name, error_feedback=ef, topk_frac=0.3)
    worst = 0.0
    for rnd in range(rounds):
        feats = [jax.random.normal(
            jax.random.fold_in(KEY, 101 * rnd + i), shp)
            for i, shp in enumerate(shapes)]
        s_out = [seq.uplink_features(i, {"h": f, "aux": 0.5})
                 for i, f in enumerate(feats)]
        c_out = coh.uplink_features_cohort(
            [(i, {"h": f, "aux": 0.5}) for i, f in enumerate(feats)])
        for a, b in zip(s_out, c_out):
            worst = max(worst, float(jnp.abs(a["h"] - b["h"]).max()))
        s_g = [seq.downlink_grads(i, f * 0.1)
               for i, f in enumerate(feats)]
        c_g = coh.downlink_grads_cohort(
            [(i, f * 0.1) for i, f in enumerate(feats)])
        for a, b in zip(s_g, c_g):
            worst = max(worst, float(jnp.abs(a - b).max()))
    # bytes: BIT-equal, not approx
    assert seq.total_bytes == coh.total_bytes
    for i in range(len(shapes)):
        assert seq.round_payload(i) == coh.round_payload(i)
        assert seq.round_payload_split(i) == coh.round_payload_split(i)
    assert worst <= 1e-6
    # residual accumulators carry the same mass, keyed identically
    assert set(seq._residuals) == set(coh._residuals)
    assert abs(seq.residual_norm() - coh.residual_norm()) \
        <= 1e-4 * max(1.0, seq.residual_norm())
    if name == "randk":
        assert seq.feature_codec._calls == coh.feature_codec._calls
        assert seq.grad_codec._calls == coh.grad_codec._calls


@pytest.mark.parametrize("name", ["fp32", "bf16", "int8", "topk",
                                  "randk"])
@pytest.mark.parametrize("ef", [False, True])
def test_cohort_equals_sequential_channel(name, ef):
    # mixed shapes exercise the (shape, dtype) bucketing; the singleton
    # shape rides a D=1 fused call
    _equiv_case(name, ef, [(8, 33), (8, 33), (8, 33), (4, 5), (1,)])


@settings(max_examples=15, deadline=None)
@given(st.sampled_from(["bf16", "int8", "topk", "randk"]),
       st.booleans(),
       st.lists(st.tuples(st.integers(1, 6), st.integers(1, 40)),
                min_size=2, max_size=6))
def test_cohort_equivalence_property(name, ef, shapes):
    _equiv_case(name, ef, shapes, rounds=2)


def test_cohort_model_legs_match_sequential():
    seq = CommChannel("fp32", dispatch_codec="int8",
                      error_feedback=True)
    coh = CommChannel("fp32", dispatch_codec="int8",
                      error_feedback=True)
    leaves = {cid: [jax.random.normal(jax.random.fold_in(KEY, cid),
                                      (9, 4)),
                    jax.random.normal(jax.random.fold_in(KEY, 50 + cid),
                                      (17,))]
              for cid in range(3)}
    for _ in range(2):
        s = {cid: seq.dispatch_leaves(cid, leaves[cid])
             for cid in range(3)}
        c = coh.dispatch_leaves_cohort(
            [(cid, leaves[cid]) for cid in range(3)])
        for cid, cl in zip(range(3), c):
            for a, b in zip(s[cid], cl):
                assert float(jnp.abs(a - b).max()) <= 1e-6
        s = {cid: seq.collect_leaves(cid, leaves[cid])
             for cid in range(3)}
        c = coh.collect_leaves_cohort(
            [(cid, leaves[cid]) for cid in range(3)])
        for cid, cl in zip(range(3), c):
            for a, b in zip(s[cid], cl):
                assert float(jnp.abs(a - b).max()) <= 1e-6
    assert seq.total_bytes == coh.total_bytes
    for cid in range(3):
        assert seq.round_dispatch(cid) == coh.round_dispatch(cid)
    assert set(seq._residuals) == set(coh._residuals)


def test_cohort_recorder_counts_match_sequential():
    from repro.observe import MetricsRegistry, Recorder
    outs = []
    for mode in ("seq", "coh"):
        reg = MetricsRegistry()
        ch = CommChannel("int8")
        ch.recorder = Recorder(metrics=reg)
        x = jax.random.normal(KEY, (6, 20))
        if mode == "seq":
            for i in range(4):
                ch.uplink_features(i, x)
        else:
            ch.uplink_features_cohort([(i, x) for i in range(4)])
        outs.append({k: v for k, v in reg.snapshot().items()
                     if k.startswith("comm.")})
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# engine integration: fused flags vs the sequential loop
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("codec,ef", [("int8", True), ("randk", True)])
def test_engine_fused_flags_match_sequential(codec, ef):
    import dataclasses

    from repro.configs import CommConfig, get_config
    from repro.core.engine import EngineConfig, S2FLEngine
    from repro.data.partition import federate
    from repro.data.synthetic import make_image_dataset
    from repro.models import SplitModel

    ds = make_image_dataset(240, seed=0)
    fed = federate(ds, 6, alpha=0.3, seed=0)

    def run(fused_comm, fused_server):
        ecfg = EngineConfig(
            mode="s2fl", rounds=2, clients_per_round=4, batch_size=8,
            local_steps=2, seed=0,
            comm=CommConfig(codec=codec, error_feedback=ef,
                            topk_frac=0.2),
            fused_comm=fused_comm, fused_server=fused_server)
        eng = S2FLEngine(SplitModel(get_config("resnet8")), fed, ecfg)
        hist = eng.run(2)
        psum = float(sum(np.asarray(w, np.float64).sum()
                         for w in jax.tree.leaves(eng.params)))
        return hist, psum, eng

    h0, p0, e0 = run(False, False)
    h1, p1, e1 = run(True, True)
    for a, b in zip(h0, h1):
        assert a["comm"] == b["comm"]          # bytes -> clock bit-equal
        assert a["clock"] == b["clock"]
        assert abs(a["loss"] - b["loss"]) < 1e-3
    assert abs(p0 - p1) < 1e-2                 # vmap numerics drift only
    assert abs(e0.channel.residual_norm()
               - e1.channel.residual_norm()) < 1e-2


@pytest.mark.slow
def test_engine_fused_flags_match_sequential_under_faults():
    """Fault teardown (mid-flight kills, quarantine, abandonment) is
    driver-level and path-independent — the fused cohort path must see
    the IDENTICAL exactly-once ledger, comm/clock trace, quarantine
    set and residual mass as the sequential loop under the same fault
    plan. Locks in the ISSUE-8 'fault-plan replay inside the fused
    cohort path' gap as verified-equivalent."""
    import dataclasses

    from repro.configs import CommConfig, get_config
    from repro.core.engine import EngineConfig, S2FLEngine
    from repro.core.faults import FaultPlan
    from repro.data.partition import federate
    from repro.data.synthetic import make_image_dataset
    from repro.models import SplitModel

    ds = make_image_dataset(160, seed=1)
    fed = federate(ds, 5, alpha=0.3, seed=1)
    mk_plan = lambda: FaultPlan.random(
        list(range(5)), 3, seed=11, kill_prob=0.3, rejoin_prob=0.6,
        mid_flight_frac=0.8, server_policy="cancel",
        residual_policy="restore")

    def run(fused):
        ecfg = EngineConfig(
            mode="s2fl", rounds=3, clients_per_round=4, batch_size=8,
            local_steps=2, seed=0,
            comm=CommConfig(codec="int8", error_feedback=True))
        ecfg = dataclasses.replace(
            ecfg,
            driver=dataclasses.replace(ecfg.driver, exec_mode="semi_async",
                                       pipeline=True, quorum=0.5,
                                       staleness_cap=2),
            fused_comm=fused, fused_server=fused)
        eng = S2FLEngine(SplitModel(get_config("resnet8")), fed, ecfg,
                         fault_plan=mk_plan())
        hist = eng.run(3)
        return hist, eng

    h0, e0 = run(False)
    h1, e1 = run(True)
    d0, d1 = e0.driver, e1.driver
    # exactly-once ledger multiset, bit-equal under both paths
    assert (d0.n_dispatched, d0.n_committed, d0.n_abandoned) == \
           (d1.n_dispatched, d1.n_committed, d1.n_abandoned)
    assert d0.n_abandoned > 0                   # the plan actually bit
    for a, b in zip(h0, h1):
        assert a["comm"] == b["comm"]
        assert a["clock"] == b["clock"]
        assert abs(a["loss"] - b["loss"]) < 1e-3
    # quarantined EF residuals: same held devices, same total mass
    assert set(e0.channel._quarantine) == set(e1.channel._quarantine)
    assert abs(e0.channel.residual_norm()
               - e1.channel.residual_norm()) < 1e-2
    assert abs(e0.channel.ef_discarded_mass
               - e1.channel.ef_discarded_mass) < 1e-6
