"""Checkpoint layer: structure-skeleton round-trips (no repr() strings),
mismatch diagnostics, and the stateful-codec/EF-residual replay contract
that full-run resume depends on."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.comm import CommChannel


def _nested_tree():
    return {"layers": [{"w": np.arange(6.0).reshape(2, 3),
                        "b": np.zeros(3)},
                       {"w": np.ones((3, 1)), "b": np.full(1, 7.0)}],
            "head": (np.eye(2), np.array([1, 2, 3])),
            "scalars": {"step": np.asarray(42)}}


def _assert_same_structure(a, b):
    assert type(a) is type(b)
    if isinstance(a, dict):
        assert sorted(a) == sorted(b)
        for k in a:
            _assert_same_structure(a[k], b[k])
    elif isinstance(a, (list, tuple)):
        assert len(a) == len(b)
        for x, y in zip(a, b):
            _assert_same_structure(x, y)
    else:
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_roundtrip_without_reference_tree(tmp_path):
    """The skeleton alone rebuilds the exact structure: dicts stay
    dicts, lists lists, tuples TUPLES (a repr()-string format cannot
    express this without eval)."""
    tree = _nested_tree()
    path = str(tmp_path / "ckpt.npz")
    save_checkpoint(path, tree, extra={"round": 3, "cid": np.int64(5)})
    restored, extra = load_checkpoint(path)
    _assert_same_structure(tree, restored)
    assert isinstance(restored["head"], tuple)
    assert isinstance(restored["layers"], list)
    # np scalars in extra crossed JSON as plain Python
    assert extra == {"round": 3, "cid": 5}


def test_roundtrip_with_reference_tree(tmp_path):
    tree = _nested_tree()
    path = str(tmp_path / "ckpt.npz")
    save_checkpoint(path, tree)
    restored, _ = load_checkpoint(path, like=tree)
    _assert_same_structure(tree, restored)


def test_mismatch_names_the_differing_paths(tmp_path):
    path = str(tmp_path / "ckpt.npz")
    save_checkpoint(path, {"a": np.zeros(2), "b": np.zeros(2)})
    with pytest.raises(ValueError, match="mismatch") as ei:
        load_checkpoint(path, like={"a": np.zeros(2), "c": np.zeros(2)})
    msg = str(ei.value)
    assert "/b" in msg and "/c" in msg


def test_save_creates_parent_directory(tmp_path):
    path = str(tmp_path / "deep" / "nested" / "ckpt.npz")
    save_checkpoint(path, {"w": np.zeros(1)})
    assert os.path.exists(path)
    restored, _ = load_checkpoint(path)
    np.testing.assert_array_equal(restored["w"], np.zeros(1))


# ---------------------------------------------------------------------------
# stateful-codec checkpoint contract (rand-k counter stream + EF residuals)
# ---------------------------------------------------------------------------
def _mk_randk():
    return CommChannel(codec="randk", error_feedback=True, topk_frac=0.25)


def _roundtrip_tensor(ch, cid, x):
    return np.asarray(ch.uplink_features(cid, x))


def test_codec_state_restore_replays_draw_stream(tmp_path):
    """export_codec_state/restore_codec_state: a restored channel's
    subsequent rand-k index draws — and therefore its decoded tensors
    and EF residuals — are identical to the uninterrupted channel's."""
    x = jnp.arange(32.0).reshape(4, 8) + 1.0
    a = _mk_randk()
    for _ in range(3):
        _roundtrip_tensor(a, 1, x)
    st = a.export_codec_state()
    assert st["feature"]["calls"] == 3
    res = a.export_residual_state()

    b = _mk_randk()
    b.restore_codec_state(st)
    b.restore_residual_state({k: jnp.asarray(v) for k, v in res.items()})
    for _ in range(4):                     # streams stay locked in step
        ya = _roundtrip_tensor(a, 1, x)
        yb = _roundtrip_tensor(b, 1, x)
        np.testing.assert_array_equal(ya, yb)
    assert a.export_codec_state() == b.export_codec_state()
    assert a.residual_norm() == pytest.approx(b.residual_norm())


def test_codec_state_survives_json(tmp_path):
    """The codec state rides the checkpoint's JSON side-channel — it
    must round-trip through an actual save/load. (Feedback off: this
    isolates the counter stream; the residual tensors travel separately
    and are covered above.)"""
    a = CommChannel(codec="randk", topk_frac=0.25)
    x = jnp.arange(16.0) + 1.0
    for _ in range(5):
        _roundtrip_tensor(a, 2, x)
    path = str(tmp_path / "codec.npz")
    save_checkpoint(path, {"dummy": np.zeros(1)},
                    extra={"codecs": a.export_codec_state()})
    _, extra = load_checkpoint(path)
    b = CommChannel(codec="randk", topk_frac=0.25)
    b.restore_codec_state(extra["codecs"])
    np.testing.assert_array_equal(_roundtrip_tensor(a, 2, x),
                                  _roundtrip_tensor(b, 2, x))


def test_reset_codecs_rewinds_to_stream_start():
    """reset_codecs + reset_feedback must reproduce a fresh channel's
    first transfer exactly (the counter rewinds to call 0)."""
    ch = _mk_randk()
    x = jnp.arange(64.0) + 1.0
    first = _roundtrip_tensor(ch, 1, x)
    for _ in range(3):
        _roundtrip_tensor(ch, 1, x)
    assert ch.export_codec_state()["feature"]["calls"] == 4
    ch.reset_codecs()
    ch.reset_feedback()
    assert ch.export_codec_state()["feature"]["calls"] == 0
    np.testing.assert_array_equal(_roundtrip_tensor(ch, 1, x), first)


def test_restore_codec_state_ignores_stateless_roles():
    """A state dict from a richer channel restores cleanly into one
    whose codecs have no state hooks (fp32 everywhere) — the restore is
    a no-op, not a crash."""
    a = _mk_randk()
    _roundtrip_tensor(a, 1, jnp.arange(8.0))
    plain = CommChannel(codec="fp32")
    plain.restore_codec_state(a.export_codec_state())
    assert plain.export_codec_state() == {}


def test_channel_export_state_roundtrips_meters():
    a = _mk_randk()
    _roundtrip_tensor(a, 1, jnp.arange(8.0) + 1.0)
    a.sim_round = 5
    a.ef_discarded_mass = 2.5
    st = a.export_state()
    b = _mk_randk()
    b.restore_state(st)
    assert b.sim_round == 5
    assert b.up_bytes == a.up_bytes
    assert b.ef_discarded_mass == 2.5
    assert b.export_codec_state() == a.export_codec_state()
