"""Sharding/dry-run machinery tests at small scale.

The production 512-device dry-run can't run inside pytest (device count
locks at first jax init — see launch/dryrun.py), so here we:
  - verify param PartitionSpecs respect divisibility and single-claim
  - lower the fused train step on a small in-process mesh via subprocess
  - unit-test the HLO collective parser on synthetic HLO text
"""
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models.params import is_def, param_specs
from repro.models.sharding import mesh_rules

# training-heavy module: the quick loop skips it (-m "not slow"; see pytest.ini)
pytestmark = pytest.mark.slow
from repro.models.transformer import model_defs
from repro.utils.hlo import collective_bytes


class FakeMesh:
    axis_names = ("data", "model")

    class _D:
        shape = (16, 16)
        size = 256
    devices = _D()


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "kimi-k2-1t-a32b",
                                  "mamba2-2.7b", "internvl2-1b",
                                  "gemma3-27b"])
def test_param_specs_divisible(arch):
    cfg = get_config(arch)
    defs = model_defs(cfg)
    specs = param_specs(defs, mesh_rules(cfg, FakeMesh()))
    flat_defs = jax.tree.leaves(defs, is_leaf=is_def)
    flat_specs = jax.tree.leaves(specs,
                                 is_leaf=lambda x: isinstance(x, P))
    assert len(flat_defs) == len(flat_specs)
    for d, s in zip(flat_defs, flat_specs):
        used = [ax for ax in s if ax is not None]
        assert len(used) == len(set(used)), (d, s)   # single-claim
        for dim, ax in zip(d.shape, s):
            if ax == "model":
                assert dim % 16 == 0, (d, s)
            if ax == "data":
                assert dim % 16 == 0, (d, s)


def test_kimi_experts_sharded_two_axes():
    """The 1T MoE must shard experts over `model` AND expert ff over
    `data` (fsdp_ff) or it cannot fit 256 chips."""
    cfg = get_config("kimi-k2-1t-a32b")
    defs = model_defs(cfg)
    specs = param_specs(defs, mesh_rules(cfg, FakeMesh()))
    moe_spec = specs["blocks"][1]["ffn"]["w_gate"]
    assert moe_spec[0] == "model" and "data" in tuple(moe_spec), moe_spec


def test_collective_parser():
    hlo = textwrap.dedent("""
      %ar = bf16[128,1024]{1,0} all-reduce(%x), replica_groups={}
      %ag.1 = f32[64,64]{1,0} all-gather(%y), dimensions={0}
      %t = (bf16[8,8]{1,0}, bf16[8,8]{1,0}) all-to-all(%a, %b)
      %cp = u32[16]{0} collective-permute(%z)
      %not_a_coll = f32[2,2]{1,0} add(%p, %q)
    """)
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 128 * 1024 * 2
    assert out["all-gather"] == 64 * 64 * 4
    assert out["all-to-all"] == 2 * 8 * 8 * 2
    assert out["collective-permute"] == 16 * 4
    assert out["_total"] == sum(out[k] for k in
                                ("all-reduce", "all-gather", "all-to-all",
                                 "collective-permute", "reduce-scatter"))


def test_collective_parser_ignores_async_done():
    hlo = ("%s = bf16[64]{0} all-gather-start(%x)\n"
           "%d = bf16[64]{0} all-gather-done(%s)\n")
    out = collective_bytes(hlo)
    assert out["all-gather"] == 64 * 2      # start counted once


DRYRUN_SMALL = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.configs import get_config, make_reduced
from repro.core.round_step import make_s2fl_train_step, train_step_shardings
from repro.launch.steps import train_inputs
from repro.models.transformer import abstract_model


cfg = make_reduced(get_config("{arch}"))
mesh = jax.make_mesh((4, 2), ("data", "model"))
step = make_s2fl_train_step(cfg, 1, 2, 0.01, dp_axes=("data",))
batch = train_inputs(cfg, batch=8, seq=32)
in_sh, out_sh = train_step_shardings(cfg, mesh, batch)
with mesh:
    c = jax.jit(step, in_shardings=in_sh,
                out_shardings=out_sh).lower(abstract_model(cfg),
                                            batch).compile()
cost = c.cost_analysis()
if isinstance(cost, (list, tuple)):   # older jaxlib: per-device list
    cost = cost[0]
assert cost["flops"] > 0
print("OK", cost["flops"])
"""


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "zamba2-1.2b"])
def test_fused_step_lowers_on_small_mesh(arch):
    """Real lower+compile of the fused S²FL step on an 8-device host mesh
    (subprocess so the device count doesn't leak into this session)."""
    r = subprocess.run(
        [sys.executable, "-c", DRYRUN_SMALL.format(arch=arch)],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root",
             # host-mesh lowering needs the CPU platform; skipping the
             # TPU probe also avoids a 60s metadata timeout on CI
             "JAX_PLATFORMS": "cpu"}, cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
