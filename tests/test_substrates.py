"""Substrate tests: data pipeline (Dirichlet properties), optimizers,
checkpointing, flops accounting — with hypothesis where it pays off."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import get_config, make_reduced
from repro.data.partition import dirichlet_partition, federate
from repro.data.synthetic import make_image_dataset, make_lm_dataset
from repro.models import SplitModel
from repro.optim import adam, clip_by_global_norm, cosine_schedule, sgd
from repro.utils.flops import (client_portion_size, full_size,
                               model_flops_6nd,
                               split_costs)

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------
@given(st.integers(0, 1000), st.sampled_from([0.1, 0.5, 1.0]))
@settings(max_examples=10, deadline=None)
def test_dirichlet_partition_properties(seed, alpha):
    labels = np.random.default_rng(seed).integers(0, 10, size=500)
    parts = dirichlet_partition(labels, 8, alpha, seed=seed)
    all_idx = np.concatenate(parts)
    assert len(all_idx) == 500
    assert len(np.unique(all_idx)) == 500          # exact partition
    assert all(len(p) >= 2 for p in parts)


def test_dirichlet_alpha_controls_heterogeneity():
    labels = np.random.default_rng(0).integers(0, 10, size=4000)

    def mean_skew(alpha):
        parts = dirichlet_partition(labels, 10, alpha, seed=0)
        from repro.core.balance import eq2_distance, label_histogram
        return np.mean([eq2_distance(label_histogram(labels[p], 10))
                        for p in parts])

    assert mean_skew(0.1) > mean_skew(1.0) > mean_skew(100.0)


def test_federate_and_iid():
    ds = make_image_dataset(300, seed=0)
    fed = federate(ds, 5, alpha=None)
    assert len(fed) == 5
    assert sum(len(v["y"]) for v in fed.values()) == 300
    lm = make_lm_dataset(50, seq_len=16, vocab=64)
    assert lm["tokens"].shape == (50, 16)
    assert (lm["labels"][:, :-1] == lm["tokens"][:, 1:]).all()
    assert lm["tokens"].max() < 64


# ---------------------------------------------------------------------------
# optim
# ---------------------------------------------------------------------------
def _quad_problem():
    params = {"w": jnp.array([3.0, -2.0]), "b": jnp.array([1.0])}
    grad_fn = jax.grad(lambda p: jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2))
    return params, grad_fn


@pytest.mark.parametrize("opt", [sgd(0.1), sgd(0.1, momentum=0.9),
                                 adam(0.1)])
def test_optimizers_converge_on_quadratic(opt):
    params, grad_fn = _quad_problem()
    state = opt.init(params)
    for step in range(150):
        g = grad_fn(params)
        params, state = opt.update(params, g, state, step)
    assert float(jnp.abs(params["w"]).max()) < 1e-2
    assert float(jnp.abs(params["b"]).max()) < 1e-2


def test_clip_by_global_norm():
    g = {"a": jnp.array([3.0, 4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 5.0, rtol=1e-6)
    np.testing.assert_allclose(
        float(jnp.linalg.norm(clipped["a"])), 1.0, rtol=1e-5)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(0)) < 0.2
    np.testing.assert_allclose(float(lr(10)), 1.0, rtol=1e-5)
    assert float(lr(99)) < 0.2


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip():
    cfg = make_reduced(get_config("zamba2-1.2b"))
    model = SplitModel(cfg)
    params = model.init(KEY)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        save_checkpoint(path, params, extra={"round": 7})
        restored, extra = load_checkpoint(path, params)
        assert extra["round"] == 7
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_mismatch_raises():
    cfg = make_reduced(get_config("internlm2-1.8b"))
    model = SplitModel(cfg)
    params = model.init(KEY)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        save_checkpoint(path, params)
        other = SplitModel(make_reduced(get_config("mamba2-2.7b"))).init(KEY)
        with pytest.raises(ValueError, match="mismatch"):
            load_checkpoint(path, other)


# ---------------------------------------------------------------------------
# flops accounting (Fig. 3 semantics)
# ---------------------------------------------------------------------------
def test_portion_sizes_monotone_in_split():
    for arch in ("internlm2-1.8b", "resnet8", "vgg16"):
        cfg = get_config(arch)
        model = SplitModel(cfg)
        sizes = [client_portion_size(model, s)
                 for s in range(1, model.n_units + 1)]
        assert all(b >= a for a, b in zip(sizes, sizes[1:]))
        assert sizes[-1] <= full_size(model)


def test_split_costs_conserve_flops():
    """Fc + Fs ≈ F_full at any split (Fig. 3: portions partition the
    model)."""
    for arch in ("internlm2-1.8b", "mamba2-2.7b", "resnet8"):
        model = SplitModel(get_config(arch))
        kw = {"seq_len": 128} if not model.is_cnn else {}
        for s in (1, 2, model.n_units // 2 or 1):
            c = split_costs(model, s, **kw)
            np.testing.assert_allclose(c["fc"] + c["fs"], c["f_full"],
                                       rtol=1e-6)
            assert c["wc_size"] > 0 and c["feat_size"] > 0


def test_param_counts_match_assignment_scale():
    """Total params are in the right ballpark for the named scales."""
    expect = {"internlm2-1.8b": (1.5e9, 2.4e9),
              "mamba2-2.7b": (2.2e9, 3.2e9),
              "gemma3-27b": (2.2e10, 3.2e10),
              "kimi-k2-1t-a32b": (0.8e12, 1.3e12),
              "deepseek-v2-lite-16b": (1.2e10, 2.0e10),
              "stablelm-3b": (2.3e9, 3.5e9),
              "zamba2-1.2b": (0.9e9, 1.9e9),
              "internvl2-1b": (3e8, 9e8)}
    for arch, (lo, hi) in expect.items():
        model = SplitModel(get_config(arch))
        n = full_size(model)
        assert lo <= n <= hi, (arch, n)


def test_model_flops_6nd_moe_uses_active():
    dense = model_flops_6nd(get_config("internlm2-1.8b"), 1000)
    assert dense > 0
    kimi = get_config("kimi-k2-1t-a32b")
    active = model_flops_6nd(kimi, 1000) / (6.0 * 1000)
    total = full_size(SplitModel(kimi))
    assert active < 0.1 * total            # 32B active of 1T
    assert active > 0.01 * total
