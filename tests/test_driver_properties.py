"""Property-based harness for the RoundDriver event pipeline.

Hypothesis (via tests/hypothesis_compat.py — skipped, not failed, when
the package is absent) drives random arrival regimes, quorums, staleness
caps, cost structures, latencies and contention capacities through the
three timelines (sync barrier, phase-sequential semi_async, phase
pipeline) and asserts the invariants the driver's design note promises:

  * the clock is monotone and every round advance is non-negative;
  * no work item is ever dropped — everything dispatched commits either
    in a window or at ``flush()``, exactly once;
  * staleness never exceeds the cap in any window;
  * with contention and latency off:
        pipelined wall-clock <= phase-sequential <= sync
    (commits can only move earlier when a group commits at the end of
    its server compute instead of the end of its download);
  * a finite shared ingress can only slow the pipelined clock, and the
    fluid max-min fair upload schedule respects per-job lower bounds;
  * full-duplex finite resources (downlink capacity, server backward
    slots, re-dispatch gating): the clock stays monotone and nothing is
    dropped under ANY (uplink, downlink, server-slot) capacities, a
    finite-resource clock never beats the infinite-resource one on a
    fixed schedule, and the cross-window ``FluidLink`` conserves bytes
    over arbitrary aggregation-window boundaries.
"""
import math

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.comm import CommChannel, FluidLink, shared_link_finish_times
from repro.core.driver import AnalyticCost, RoundDriver, _ServerQueue
from repro.core.scheduler import FixedSplitScheduler, SlidingSplitScheduler
from repro.core.simulation import make_device_grid
from repro.core.split import SplitPlan

PLAN = SplitPlan(n_units=8, split_points=(1, 2, 4))


def _rand_costs(rng):
    """Random-but-plausible per-split Eq.-1 quantities (positive, spread
    over the regimes where stragglers and ties both occur)."""
    out = {}
    for s in PLAN.split_points:
        out[s] = dict(wc_size=float(rng.uniform(1e4, 2e6)),
                      feat_size=float(rng.uniform(1e2, 2e4)),
                      fc=float(rng.uniform(1e7, 3e9)),
                      fs=float(rng.uniform(1e7, 3e9)))
    return out


def _drive(costs, *, n_devices, rounds, per_round, quorum, cap, seed,
           mode="semi_async", pipeline=False, latency=0.0,
           uplink_capacity=0.0, downlink_capacity=0.0,
           server_concurrency=0, gate_redispatch=False,
           latency_dist="constant",
           scheduler=SlidingSplitScheduler):
    devices = make_device_grid(n_devices, seed=seed)
    ch = CommChannel(codec="fp32", latency=latency,
                     uplink_capacity=uplink_capacity,
                     downlink_capacity=downlink_capacity,
                     latency_dist=latency_dist)
    drv = RoundDriver(scheduler(PLAN), AnalyticCost(ch, costs, p=32),
                      devices, mode=mode, staleness_cap=cap,
                      quorum=quorum, pipeline=pipeline,
                      server_concurrency=server_concurrency,
                      gate_redispatch=gate_redispatch)
    rng = np.random.default_rng(seed)
    recs = []
    for r in range(rounds):
        part = rng.choice(devices, size=per_round, replace=False)
        recs.append(drv.run_round(part))
    flushed, _ = drv.flush()
    return drv, recs, flushed


DRIVER_ARGS = dict(
    seed=st.integers(0, 2**31 - 1),
    n_devices=st.integers(2, 9),
    rounds=st.integers(1, 7),
    quorum=st.floats(0.1, 1.0),
    cap=st.integers(0, 3),
)


@given(**DRIVER_ARGS)
@settings(max_examples=40, deadline=None)
def test_clock_monotone_and_no_dropped_work(seed, n_devices, rounds,
                                            quorum, cap):
    rng = np.random.default_rng(seed)
    costs = _rand_costs(rng)
    per_round = int(rng.integers(1, n_devices + 1))
    for pipeline in (False, True):
        drv, recs, flushed = _drive(
            costs, n_devices=n_devices, rounds=rounds,
            per_round=per_round, quorum=quorum, cap=cap, seed=seed,
            pipeline=pipeline)
        # monotone timeline
        clocks = [0.0] + [r.clock for r in recs] + [drv.clock]
        assert all(b >= a for a, b in zip(clocks, clocks[1:]))
        assert all(r.round_time >= 0.0 for r in recs)
        # zero dropped work: every dispatched item commits exactly once
        committed = [k for r in recs for k in r.committed] + list(flushed)
        assert sorted(committed) == sorted(
            c for r in recs for c in r.splits)
        assert not drv._pending and not drv._downloads
        # staleness bounded in every window
        for r in recs:
            assert all(v <= cap for v in r.staleness.values()), r


@given(**DRIVER_ARGS)
@settings(max_examples=40, deadline=None)
def test_pipelined_le_sequential_le_sync(seed, n_devices, rounds, quorum,
                                         cap):
    """With contention and latency off every commit can only move
    earlier under phase overlap, so the three flushed wall-clocks are
    totally ordered (static link; the same wire bytes cross either
    way)."""
    rng = np.random.default_rng(seed)
    costs = _rand_costs(rng)
    per_round = int(rng.integers(1, n_devices + 1))
    kw = dict(n_devices=n_devices, rounds=rounds, per_round=per_round,
              quorum=quorum, cap=cap, seed=seed)
    sync, _, _ = _drive(costs, mode="sync", **kw)
    seq, _, _ = _drive(costs, mode="semi_async", **kw)
    pipe, _, _ = _drive(costs, mode="semi_async", pipeline=True, **kw)
    tol = 1e-9 * max(sync.clock, 1.0)
    assert pipe.clock <= seq.clock + tol
    assert seq.clock <= sync.clock + tol
    assert pipe.comm == pytest.approx(seq.comm) == pytest.approx(sync.comm)


@given(seed=st.integers(0, 2**31 - 1),
       n_devices=st.integers(2, 8),
       rounds=st.integers(1, 6),
       capacity=st.floats(1e5, 1e7))
@settings(max_examples=30, deadline=None)
def test_contention_only_slows_the_pipeline(seed, n_devices, rounds,
                                            capacity):
    """A finite shared ingress stretches concurrent uploads, so the
    pipelined clock with contention is >= the uncontended one. Fixed
    splits keep the two runs' schedules identical, isolating the
    contention effect from the scheduler's reaction to it."""
    rng = np.random.default_rng(seed)
    costs = _rand_costs(rng)
    kw = dict(n_devices=n_devices, rounds=rounds, per_round=n_devices,
              quorum=1.0, cap=1, seed=seed, pipeline=True,
              scheduler=FixedSplitScheduler)
    free, _, _ = _drive(costs, **kw)
    jam, _, _ = _drive(costs, uplink_capacity=capacity, **kw)
    assert jam.clock >= free.clock - 1e-9 * max(free.clock, 1.0)


@given(seed=st.integers(0, 2**31 - 1),
       latency=st.floats(0.001, 0.5))
@settings(max_examples=20, deadline=None)
def test_latency_priced_consistently_across_modes(seed, latency):
    """Four messages per device-round: the atomic Eq.-1 path and the
    phase decomposition (2 on upload + 2 on download) must charge the
    same total, so latency shifts both clocks without breaking the
    pipelined <= sequential ordering."""
    rng = np.random.default_rng(seed)
    costs = _rand_costs(rng)
    kw = dict(n_devices=5, rounds=4, per_round=3, quorum=0.5, cap=1,
              seed=seed, latency=latency)
    seq, _, _ = _drive(costs, mode="semi_async", **kw)
    pipe, _, _ = _drive(costs, mode="semi_async", pipeline=True, **kw)
    base_seq, _, _ = _drive(costs, mode="semi_async",
                            **{**kw, "latency": 0.0})
    assert pipe.clock <= seq.clock + 1e-9 * max(seq.clock, 1.0)
    assert seq.clock >= base_seq.clock    # latency can only add time


# ---------------------------------------------------------------------------
# the fluid max-min fair shared-link schedule
# ---------------------------------------------------------------------------
@given(seed=st.integers(0, 2**31 - 1),
       n_jobs=st.integers(1, 12),
       capacity=st.floats(10.0, 1e4))
@settings(max_examples=50, deadline=None)
def test_shared_link_schedule_invariants(seed, n_jobs, capacity):
    rng = np.random.default_rng(seed)
    jobs = [(float(rng.uniform(0, 50)), float(rng.uniform(0, 1e4)),
             float(rng.uniform(1.0, 1e3))) for _ in range(n_jobs)]
    fins = shared_link_finish_times(jobs, capacity)
    for (a, b, r), f in zip(jobs, fins):
        # never faster than the job's best case on the contended link
        best = a + b / min(r, capacity)
        assert f >= best - 1e-6 * max(best, 1.0)
    # uncontended: exactly arrival + size/rate
    free = shared_link_finish_times(jobs, math.inf)
    for (a, b, r), f in zip(jobs, free):
        assert f == pytest.approx(a + b / r)
    # more capacity never finishes later
    wider = shared_link_finish_times(jobs, capacity * 2.0)
    for f2, f1 in zip(wider, fins):
        assert f2 <= f1 + 1e-6 * max(f1, 1.0)


# ---------------------------------------------------------------------------
# full-duplex finite resources (server slots, downlink contention, gating)
# ---------------------------------------------------------------------------
def _resource_kw(rng):
    """A random resource regime: each capacity is off or finite, server
    slots 0 (unbounded) .. 3, gating on/off, latency draws on/off."""
    return dict(
        uplink_capacity=float(rng.choice([0.0, rng.uniform(1e5, 1e7)])),
        downlink_capacity=float(rng.choice([0.0, rng.uniform(1e5, 1e7)])),
        server_concurrency=int(rng.integers(0, 4)),
        gate_redispatch=bool(rng.integers(0, 2)),
        latency=float(rng.choice([0.0, rng.uniform(0.0, 0.3)])),
        latency_dist=str(rng.choice(["constant", "uniform",
                                     "lognormal", "exp"])))


@given(**DRIVER_ARGS)
@settings(max_examples=40, deadline=None)
def test_clock_monotone_under_any_resource_caps(seed, n_devices, rounds,
                                                quorum, cap):
    """The core liveness/safety invariants survive EVERY combination of
    (uplink, downlink, server-slot) capacities, gating and latency
    draws: the clock never goes backwards, nothing dispatched is ever
    dropped or double-committed, and staleness stays within the cap."""
    rng = np.random.default_rng(seed)
    costs = _rand_costs(rng)
    per_round = int(rng.integers(1, n_devices + 1))
    for mode in ("sync", "semi_async"):
        drv, recs, flushed = _drive(
            costs, n_devices=n_devices, rounds=rounds,
            per_round=per_round, quorum=quorum, cap=cap, seed=seed,
            mode=mode, pipeline=True, **_resource_kw(rng))
        clocks = [0.0] + [r.clock for r in recs] + [drv.clock]
        assert all(b >= a for a, b in zip(clocks, clocks[1:]))
        assert all(r.round_time >= 0.0 for r in recs)
        committed = [k for r in recs for k in r.committed] + list(flushed)
        assert sorted(committed) == sorted(
            c for r in recs for c in r.splits)
        assert not drv._pending and not drv._downloads
        assert not drv._flights          # every flight fully drained
        for r in recs:
            assert all(v <= cap for v in r.staleness.values()), r


@given(**DRIVER_ARGS)
@settings(max_examples=30, deadline=None)
def test_finite_resources_never_beat_infinite(seed, n_devices, rounds,
                                              quorum, cap):
    """On a FIXED schedule (FixedSplitScheduler keeps the two runs'
    dispatches identical) every finite resource — shared ingress, shared
    egress, bounded server concurrency, re-dispatch gating — can only
    delay events, so the resource-constrained flushed clock is >= the
    free-overlap one, with identical wire traffic."""
    rng = np.random.default_rng(seed)
    costs = _rand_costs(rng)
    per_round = int(rng.integers(1, n_devices + 1))
    kw = dict(n_devices=n_devices, rounds=rounds, per_round=per_round,
              quorum=quorum, cap=cap, seed=seed, pipeline=True,
              scheduler=FixedSplitScheduler)
    free, _, _ = _drive(costs, **kw)
    jam, _, _ = _drive(
        costs, uplink_capacity=float(rng.uniform(1e5, 1e7)),
        downlink_capacity=float(rng.uniform(1e5, 1e7)),
        server_concurrency=int(rng.integers(1, 4)),
        gate_redispatch=True, **kw)
    assert jam.clock >= free.clock - 1e-9 * max(free.clock, 1.0)
    assert jam.comm == pytest.approx(free.comm)


@given(seed=st.integers(0, 2**31 - 1),
       n_batches=st.integers(1, 6),
       capacity=st.floats(10.0, 1e4))
@settings(max_examples=50, deadline=None)
def test_fluid_link_byte_conservation_across_windows(seed, n_batches,
                                                     capacity):
    """A FluidLink carrying flows across aggregation windows conserves
    bytes: at every checkpoint each flow's in-flight remainder is within
    [0, size] and non-increasing, the aggregate drain between
    checkpoints never exceeds capacity * dt, and once the last solve's
    horizon passes everything has drained exactly."""
    rng = np.random.default_rng(seed)
    link = FluidLink(capacity)
    t0 = 0.0
    checkpoints = [0.0]
    for _ in range(n_batches):           # batches at increasing clocks
        for _ in range(int(rng.integers(1, 5))):
            link.submit(t0 + float(rng.uniform(0, 20)),
                        float(rng.uniform(0, 5e3)),
                        float(rng.uniform(1.0, 1e3)))
        t0 += float(rng.uniform(5, 40))
        checkpoints.append(t0)
    total = link.submitted_bytes
    fins = link.solve()
    prev = None
    prev_t = None
    for t in sorted(checkpoints + [max(fins) if fins else 0.0]):
        rem = link.remaining_at(t)
        sizes = link._bytes
        assert all(-1e-6 <= r <= b + 1e-6
                   for r, b in zip(rem, sizes))
        if prev is not None:
            drained = sum(prev) - sum(rem)
            assert drained >= -1e-6              # monotone drain
            assert drained <= capacity * (t - prev_t) + 1e-6 * total \
                + 1e-6                           # capacity respected
        prev, prev_t = rem, t
    # everything drains by the solved horizon, and nothing before its
    # own best case
    assert sum(link.remaining_at(max(fins) if fins else 0.0)) \
        == pytest.approx(0.0, abs=1e-5 * max(total, 1.0))
    for (a, b, r), f in zip(zip(link._arrive, link._bytes, link._caps),
                            fins):
        best = a + b / min(r, capacity)
        assert f >= best - 1e-6 * max(best, 1.0)


@given(seed=st.integers(0, 2**31 - 1),
       n_jobs=st.integers(1, 15),
       slots=st.integers(1, 4))
@settings(max_examples=50, deadline=None)
def test_server_queue_fifo_invariants(seed, n_jobs, slots):
    """The finite server queue: no job finishes before its own work
    could, at most ``slots`` jobs overlap at any instant, more slots
    never finish later, and infinite slots degenerate to
    arrival + duration."""
    rng = np.random.default_rng(seed)
    q = _ServerQueue(slots)
    jobs = [(float(rng.uniform(0, 50)), float(rng.uniform(0.1, 20)))
            for _ in range(n_jobs)]
    for a, d in jobs:
        q.add(a, d)
    fins = q.solve()
    for (a, d), f in zip(jobs, fins):
        assert f >= a + d - 1e-9
    # concurrency bound: starts/finishes define at most `slots` overlaps
    starts = [f - d for (a, d), f in zip(jobs, fins)]
    for (a, d), f in zip(jobs, fins):
        mid = f - 0.5 * d
        running = sum(1 for s, g in zip(starts, fins) if s < mid < g)
        assert running <= slots
    wide = _ServerQueue(slots + 1)
    for a, d in jobs:
        wide.add(a, d)
    for f2, f1 in zip(wide.solve(), fins):
        assert f2 <= f1 + 1e-9
    free = _ServerQueue(math.inf)
    for a, d in jobs:
        free.add(a, d)
    for (a, d), f in zip(jobs, free.solve()):
        assert f == pytest.approx(a + d)


@given(**DRIVER_ARGS)
@settings(max_examples=20, deadline=None)
def test_driver_drains_its_links_completely(seed, n_devices, rounds,
                                            quorum, cap):
    """Driver-level byte conservation: after flush() every byte ever
    submitted to the cross-window uplink/downlink FluidLinks has
    drained (nothing is lost at an aggregation-window boundary)."""
    rng = np.random.default_rng(seed)
    costs = _rand_costs(rng)
    per_round = int(rng.integers(1, n_devices + 1))
    drv, _, _ = _drive(
        costs, n_devices=n_devices, rounds=rounds, per_round=per_round,
        quorum=quorum, cap=cap, seed=seed, pipeline=True,
        uplink_capacity=float(rng.uniform(1e5, 1e7)),
        downlink_capacity=float(rng.uniform(1e5, 1e7)),
        server_concurrency=int(rng.integers(0, 3)))
    for link in (drv._uplink, drv._downlink):
        if link is None or not len(link):
            continue
        rem = link.remaining_at(drv.clock)
        assert sum(rem) == pytest.approx(
            0.0, abs=1e-6 * max(link.submitted_bytes, 1.0))
