"""Property-based harness for the RoundDriver event pipeline.

Hypothesis (via tests/hypothesis_compat.py — skipped, not failed, when
the package is absent) drives random arrival regimes, quorums, staleness
caps, cost structures, latencies and contention capacities through the
three timelines (sync barrier, phase-sequential semi_async, phase
pipeline) and asserts the invariants the driver's design note promises:

  * the clock is monotone and every round advance is non-negative;
  * no work item is ever dropped — everything dispatched commits either
    in a window or at ``flush()``, exactly once;
  * staleness never exceeds the cap in any window;
  * with contention and latency off:
        pipelined wall-clock <= phase-sequential <= sync
    (commits can only move earlier when a group commits at the end of
    its server compute instead of the end of its download);
  * a finite shared ingress can only slow the pipelined clock, and the
    fluid max-min fair upload schedule respects per-job lower bounds.
"""
import math

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.comm import CommChannel, shared_link_finish_times
from repro.core.driver import AnalyticCost, RoundDriver
from repro.core.scheduler import FixedSplitScheduler, SlidingSplitScheduler
from repro.core.simulation import make_device_grid
from repro.core.split import SplitPlan

PLAN = SplitPlan(n_units=8, split_points=(1, 2, 4))


def _rand_costs(rng):
    """Random-but-plausible per-split Eq.-1 quantities (positive, spread
    over the regimes where stragglers and ties both occur)."""
    out = {}
    for s in PLAN.split_points:
        out[s] = dict(wc_size=float(rng.uniform(1e4, 2e6)),
                      feat_size=float(rng.uniform(1e2, 2e4)),
                      fc=float(rng.uniform(1e7, 3e9)),
                      fs=float(rng.uniform(1e7, 3e9)))
    return out


def _drive(costs, *, n_devices, rounds, per_round, quorum, cap, seed,
           mode="semi_async", pipeline=False, latency=0.0,
           uplink_capacity=0.0, scheduler=SlidingSplitScheduler):
    devices = make_device_grid(n_devices, seed=seed)
    ch = CommChannel(codec="fp32", latency=latency,
                     uplink_capacity=uplink_capacity)
    drv = RoundDriver(scheduler(PLAN), AnalyticCost(ch, costs, p=32),
                      devices, mode=mode, staleness_cap=cap,
                      quorum=quorum, pipeline=pipeline)
    rng = np.random.default_rng(seed)
    recs = []
    for r in range(rounds):
        part = rng.choice(devices, size=per_round, replace=False)
        recs.append(drv.run_round(part))
    flushed, _ = drv.flush()
    return drv, recs, flushed


DRIVER_ARGS = dict(
    seed=st.integers(0, 2**31 - 1),
    n_devices=st.integers(2, 9),
    rounds=st.integers(1, 7),
    quorum=st.floats(0.1, 1.0),
    cap=st.integers(0, 3),
)


@given(**DRIVER_ARGS)
@settings(max_examples=40, deadline=None)
def test_clock_monotone_and_no_dropped_work(seed, n_devices, rounds,
                                            quorum, cap):
    rng = np.random.default_rng(seed)
    costs = _rand_costs(rng)
    per_round = int(rng.integers(1, n_devices + 1))
    for pipeline in (False, True):
        drv, recs, flushed = _drive(
            costs, n_devices=n_devices, rounds=rounds,
            per_round=per_round, quorum=quorum, cap=cap, seed=seed,
            pipeline=pipeline)
        # monotone timeline
        clocks = [0.0] + [r.clock for r in recs] + [drv.clock]
        assert all(b >= a for a, b in zip(clocks, clocks[1:]))
        assert all(r.round_time >= 0.0 for r in recs)
        # zero dropped work: every dispatched item commits exactly once
        committed = [k for r in recs for k in r.committed] + list(flushed)
        assert sorted(committed) == sorted(
            c for r in recs for c in r.splits)
        assert not drv._pending and not drv._downloads
        # staleness bounded in every window
        for r in recs:
            assert all(v <= cap for v in r.staleness.values()), r


@given(**DRIVER_ARGS)
@settings(max_examples=40, deadline=None)
def test_pipelined_le_sequential_le_sync(seed, n_devices, rounds, quorum,
                                         cap):
    """With contention and latency off every commit can only move
    earlier under phase overlap, so the three flushed wall-clocks are
    totally ordered (static link; the same wire bytes cross either
    way)."""
    rng = np.random.default_rng(seed)
    costs = _rand_costs(rng)
    per_round = int(rng.integers(1, n_devices + 1))
    kw = dict(n_devices=n_devices, rounds=rounds, per_round=per_round,
              quorum=quorum, cap=cap, seed=seed)
    sync, _, _ = _drive(costs, mode="sync", **kw)
    seq, _, _ = _drive(costs, mode="semi_async", **kw)
    pipe, _, _ = _drive(costs, mode="semi_async", pipeline=True, **kw)
    tol = 1e-9 * max(sync.clock, 1.0)
    assert pipe.clock <= seq.clock + tol
    assert seq.clock <= sync.clock + tol
    assert pipe.comm == pytest.approx(seq.comm) == pytest.approx(sync.comm)


@given(seed=st.integers(0, 2**31 - 1),
       n_devices=st.integers(2, 8),
       rounds=st.integers(1, 6),
       capacity=st.floats(1e5, 1e7))
@settings(max_examples=30, deadline=None)
def test_contention_only_slows_the_pipeline(seed, n_devices, rounds,
                                            capacity):
    """A finite shared ingress stretches concurrent uploads, so the
    pipelined clock with contention is >= the uncontended one. Fixed
    splits keep the two runs' schedules identical, isolating the
    contention effect from the scheduler's reaction to it."""
    rng = np.random.default_rng(seed)
    costs = _rand_costs(rng)
    kw = dict(n_devices=n_devices, rounds=rounds, per_round=n_devices,
              quorum=1.0, cap=1, seed=seed, pipeline=True,
              scheduler=FixedSplitScheduler)
    free, _, _ = _drive(costs, **kw)
    jam, _, _ = _drive(costs, uplink_capacity=capacity, **kw)
    assert jam.clock >= free.clock - 1e-9 * max(free.clock, 1.0)


@given(seed=st.integers(0, 2**31 - 1),
       latency=st.floats(0.001, 0.5))
@settings(max_examples=20, deadline=None)
def test_latency_priced_consistently_across_modes(seed, latency):
    """Four messages per device-round: the atomic Eq.-1 path and the
    phase decomposition (2 on upload + 2 on download) must charge the
    same total, so latency shifts both clocks without breaking the
    pipelined <= sequential ordering."""
    rng = np.random.default_rng(seed)
    costs = _rand_costs(rng)
    kw = dict(n_devices=5, rounds=4, per_round=3, quorum=0.5, cap=1,
              seed=seed, latency=latency)
    seq, _, _ = _drive(costs, mode="semi_async", **kw)
    pipe, _, _ = _drive(costs, mode="semi_async", pipeline=True, **kw)
    base_seq, _, _ = _drive(costs, mode="semi_async",
                            **{**kw, "latency": 0.0})
    assert pipe.clock <= seq.clock + 1e-9 * max(seq.clock, 1.0)
    assert seq.clock >= base_seq.clock    # latency can only add time


# ---------------------------------------------------------------------------
# the fluid max-min fair shared-link schedule
# ---------------------------------------------------------------------------
@given(seed=st.integers(0, 2**31 - 1),
       n_jobs=st.integers(1, 12),
       capacity=st.floats(10.0, 1e4))
@settings(max_examples=50, deadline=None)
def test_shared_link_schedule_invariants(seed, n_jobs, capacity):
    rng = np.random.default_rng(seed)
    jobs = [(float(rng.uniform(0, 50)), float(rng.uniform(0, 1e4)),
             float(rng.uniform(1.0, 1e3))) for _ in range(n_jobs)]
    fins = shared_link_finish_times(jobs, capacity)
    for (a, b, r), f in zip(jobs, fins):
        # never faster than the job's best case on the contended link
        best = a + b / min(r, capacity)
        assert f >= best - 1e-6 * max(best, 1.0)
    # uncontended: exactly arrival + size/rate
    free = shared_link_finish_times(jobs, math.inf)
    for (a, b, r), f in zip(jobs, free):
        assert f == pytest.approx(a + b / r)
    # more capacity never finishes later
    wider = shared_link_finish_times(jobs, capacity * 2.0)
    for f2, f1 in zip(wider, fins):
        assert f2 <= f1 + 1e-6 * max(f1, 1.0)
