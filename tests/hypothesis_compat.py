"""Optional-hypothesis shim: this image ships without the `hypothesis`
package, which used to fail three test modules at import time. Importing
``given / settings / st`` from here keeps every non-property test
running; when hypothesis is absent the property tests are collected but
skipped with a reason string (strategy constructors degrade to inert
placeholders, so decoration-time ``st.foo(...)`` calls stay legal)."""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False
    _REASON = ("hypothesis not installed in this image; property tests "
               "need it")

    def settings(*_a, **_k):
        return lambda f: f

    def given(*_a, **_k):
        return lambda f: pytest.mark.skip(reason=_REASON)(f)

    class _Strategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
