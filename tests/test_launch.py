"""Launch-layer tests: the train.py flag surface (argparse round-trip
for everything added since the observability/fusion/fault PRs), the
restartable service loop (checkpoint → resume equality through the real
CLI entry point), and serve/dryrun smoke coverage."""
import json
import os

import numpy as np
import pytest

from repro.launch.train import build_parser


# ---------------------------------------------------------------------------
# argparse round-trip: every flag the service loop grew
# ---------------------------------------------------------------------------
def test_parser_defaults_are_off():
    args = build_parser().parse_args([])
    assert args.fused_comm is False and args.fused_server is False
    assert args.trace_out is None and args.metrics_out is None
    assert args.fault_plan == "" and args.fault_kill_prob == 0.0
    assert args.fault_rejoin_prob == 0.5 and args.fault_seed == 0
    assert args.fault_server_policy == "cancel"
    assert args.fault_residual_policy == "restore"
    assert args.checkpoint_every == 0
    assert args.checkpoint_dir == "checkpoints"
    assert args.resume_from == ""


def test_parser_roundtrips_fusion_and_observability_flags():
    args = build_parser().parse_args([
        "--fused-comm", "--fused-server",
        "--trace-out", "trace.json",
        "--metrics-out", "metrics.jsonl", "--metrics-every", "3"])
    assert args.fused_comm is True and args.fused_server is True
    assert args.trace_out == "trace.json"
    assert args.metrics_out == "metrics.jsonl"
    assert args.metrics_every == 3


def test_parser_roundtrips_fault_and_resume_flags():
    args = build_parser().parse_args([
        "--fault-plan", "plan.json",
        "--fault-kill-prob", "0.25", "--fault-rejoin-prob", "0.75",
        "--fault-seed", "7",
        "--fault-server-policy", "orphan",
        "--fault-residual-policy", "discard",
        "--checkpoint-every", "5", "--checkpoint-dir", "snaps",
        "--resume-from", "snaps/round00005.npz"])
    assert args.fault_plan == "plan.json"
    assert args.fault_kill_prob == 0.25
    assert args.fault_rejoin_prob == 0.75
    assert args.fault_seed == 7
    assert args.fault_server_policy == "orphan"
    assert args.fault_residual_policy == "discard"
    assert args.checkpoint_every == 5
    assert args.checkpoint_dir == "snaps"
    assert args.resume_from == "snaps/round00005.npz"


def test_parser_rejects_unknown_policies():
    with pytest.raises(SystemExit):
        build_parser().parse_args(["--fault-server-policy", "shrug"])
    with pytest.raises(SystemExit):
        build_parser().parse_args(["--fault-residual-policy", "maybe"])


# ---------------------------------------------------------------------------
# dryrun.py: main() control flow with the heavy compile stubbed out
# ---------------------------------------------------------------------------
def test_dryrun_main_single_pair_and_json(tmp_path, monkeypatch):
    from repro.launch import dryrun
    calls = []

    def stub(arch, shape, *, multi_pod=False, verbose=True, **kw):
        calls.append((arch, shape, multi_pod, kw))
        return {"arch": arch, "shape": shape, "hlo_flops": 1.0}

    monkeypatch.setattr(dryrun, "dryrun_one", stub)
    out = str(tmp_path / "dry.json")
    rc = dryrun.main(["--arch", "internlm2-1.8b", "--shape", "train_4k",
                      "--split", "2", "--json", out])
    assert rc == 0
    assert calls == [("internlm2-1.8b", "train_4k", False, {"split": 2})]
    with open(out) as f:
        recs = json.load(f)
    assert recs[0]["arch"] == "internlm2-1.8b"


def test_dryrun_main_counts_errors(tmp_path, monkeypatch):
    from repro.launch import dryrun

    def boom(arch, shape, **kw):
        raise RuntimeError("compile exploded")

    monkeypatch.setattr(dryrun, "dryrun_one", boom)
    out = str(tmp_path / "dry.json")
    rc = dryrun.main(["--arch", "internlm2-1.8b", "--shape", "train_4k",
                      "--json", out])
    assert rc == 1                        # incremental JSON still written
    with open(out) as f:
        recs = json.load(f)
    assert "compile exploded" in recs[0]["error"]


def test_dryrun_main_requires_arch_and_shape():
    from repro.launch import dryrun
    with pytest.raises(AssertionError, match="--arch and --shape"):
        dryrun.main(["--arch", "internlm2-1.8b"])


# ---------------------------------------------------------------------------
# serve.py: tiny real decode (reduced model, 2 tokens)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_serve_generates_tokens(capsys):
    from repro.launch import serve
    serve.main(["--arch", "internlm2-1.8b", "--reduced",
                "--batch", "2", "--prompt-len", "4", "--gen", "2"])
    out = capsys.readouterr().out
    assert "generated:" in out and "tok/s" in out


@pytest.mark.slow
def test_serve_generate_shapes_and_determinism():
    import jax

    from repro.configs import get_config, make_reduced
    from repro.launch.serve import generate
    from repro.models import SplitModel
    cfg = make_reduced(get_config("internlm2-1.8b"))
    model = SplitModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0,
                                cfg.vocab_size)
    a = generate(cfg, params, tokens, steps=3)
    b = generate(cfg, params, tokens, steps=3)
    assert a.shape == (2, 3)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(np.asarray(a).max()) < cfg.vocab_size


# ---------------------------------------------------------------------------
# the restartable service loop, end to end through main()
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_train_checkpoint_resume_reproduces_history(tmp_path):
    """Run 4 rounds with --checkpoint-every 2, then resume the same
    config from the round-2 snapshot: the resumed run's history and
    final clock must equal the uninterrupted run's (fp32 sync path)."""
    from repro.launch.train import main
    ckdir = str(tmp_path / "snaps")
    out_a = str(tmp_path / "a.json")
    out_b = str(tmp_path / "b.json")
    base = ["--arch", "resnet8", "--mode", "s2fl", "--rounds", "4",
            "--clients", "4", "--per-round", "2", "--batch-size", "4",
            "--n-train", "64", "--eval-every", "99", "--seed", "1"]
    main(base + ["--checkpoint-every", "2", "--checkpoint-dir", ckdir,
                 "--out", out_a])
    snap = os.path.join(ckdir, "round00002.npz")
    assert os.path.exists(snap)
    assert os.path.exists(os.path.join(ckdir, "round00004.npz"))

    main(base + ["--resume-from", snap, "--out", out_b])
    with open(out_a) as f:
        a = json.load(f)
    with open(out_b) as f:
        b = json.load(f)
    assert len(a["history"]) == len(b["history"]) == 4
    assert b["history"] == a["history"]          # bit-exact floats
    assert b["clock"] == a["clock"]
    assert b["summary"]["final_loss"] == a["summary"]["final_loss"]


@pytest.mark.slow
def test_train_fault_flags_drive_chaos_run(tmp_path):
    """--fault-kill-prob arms the seeded churn process through the real
    CLI; the summary ledger balances and a plan FILE round-trips."""
    from repro.core.faults import FaultPlan
    from repro.launch.train import main
    out = str(tmp_path / "chaos.json")
    plan_file = str(tmp_path / "plan.json")
    FaultPlan.random(list(range(4)), 3, seed=5,
                     kill_prob=0.4).to_file(plan_file)
    main(["--arch", "resnet8", "--mode", "s2fl", "--rounds", "3",
          "--clients", "4", "--per-round", "3", "--batch-size", "4",
          "--n-train", "64", "--eval-every", "99",
          "--exec-mode", "semi_async", "--pipeline",
          "--fault-plan", plan_file, "--out", out])
    with open(out) as f:
        rec = json.load(f)
    s = rec["summary"]
    assert s["dispatched"] == s["committed"] + s["abandoned"]
    assert s["dispatched"] > 0
