"""Per-assigned-architecture smoke tests: instantiate the REDUCED variant
of the same family (2 layers, d_model<=512, <=4 experts), run one forward
and one train step on CPU, assert output shapes + finiteness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs, make_reduced
from repro.models import SplitModel
from repro.models.frontends import synth_frontend_embeds
from repro.models.transformer import decode_step, forward, prefill

# training-heavy module: the quick loop skips it (-m "not slow"; see pytest.ini)
pytestmark = pytest.mark.slow


LM_ARCHS = [a for a in list_configs()
            if not hasattr(get_config(a), "family")]
CNN_ARCHS = [a for a in list_configs() if hasattr(get_config(a), "family")]

KEY = jax.random.PRNGKey(0)


def _lm_batch(cfg, B=2, S=32):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.frontend:
        batch["prefix"] = synth_frontend_embeds(cfg, KEY, B)
    return batch


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_reduced_forward_and_train_step(arch):
    cfg = make_reduced(get_config(arch))
    assert cfg.n_layers <= 2 and cfg.d_model <= 512 and cfg.n_experts <= 4
    model = SplitModel(cfg)
    params = model.init(KEY)
    batch = _lm_batch(cfg)
    B, S = batch["tokens"].shape

    logits, aux = forward(cfg, params, batch["tokens"], batch.get("prefix"))
    P = cfg.n_frontend_tokens if cfg.frontend else 0
    assert logits.shape == (B, P + S, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    # one full train step (loss + grad + SGD)
    def loss_fn(p):
        l, _ = model.full_loss(p, batch)
        return l

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    new = jax.tree.map(lambda w, g: w - 0.01 * g.astype(w.dtype),
                       params, grads)
    loss2, _ = model.full_loss(new, batch)
    assert np.isfinite(float(loss2))
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert gnorm > 0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_reduced_split_halves_match_full(arch):
    cfg = make_reduced(get_config(arch))
    model = SplitModel(cfg)
    params = model.init(KEY)
    batch = _lm_batch(cfg)
    full, _ = model.full_loss(params, batch)
    for split in (1, 2):
        feats = model.client_forward(params, batch, split)
        half, _ = model.server_loss(params, feats, batch, split)
        np.testing.assert_allclose(float(full), float(half), rtol=1e-5)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_reduced_prefill_decode_consistency(arch):
    cfg = make_reduced(get_config(arch))
    if cfg.n_experts:
        # MoE top-k routing flips under 1e-6 perturbations; covered by the
        # dense archs — here we only check finiteness of the decode path.
        pass
    cfg = dataclasses.replace(cfg, param_dtype="float32")
    model = SplitModel(cfg)
    params = model.init(KEY)
    B, S, G = 2, 24, 4
    tokens = jax.random.randint(KEY, (B, S + G), 0, cfg.vocab_size)
    logits_full, _ = forward(cfg, params, tokens)
    lg, caches, _ = prefill(cfg, params, tokens[:, :S], max_len=S + G)
    errs = [float(jnp.abs(lg[:, -1] - logits_full[:, S - 1]).max())]
    for t in range(G):
        lg, caches = decode_step(cfg, params, tokens[:, S + t:S + t + 1],
                                 caches, jnp.asarray(S + t))
        errs.append(float(jnp.abs(lg[:, 0] - logits_full[:, S + t]).max()))
        assert np.isfinite(np.asarray(lg, np.float32)).all()
    if not cfg.n_experts:
        assert max(errs) < 1e-3, errs


@pytest.mark.parametrize("arch", CNN_ARCHS)
def test_cnn_smoke(arch):
    cfg = get_config(arch)
    model = SplitModel(cfg)
    params = model.init(KEY)
    x = jax.random.normal(KEY, (2, cfg.image_size, cfg.image_size,
                                cfg.in_channels))
    y = jnp.array([0, 1])
    loss, met = model.full_loss(params, {"x": x, "y": y})
    assert np.isfinite(float(loss))
    # split halves agree
    feats = model.client_forward(params, {"x": x, "y": y}, 1)
    half, _ = model.server_loss(params, feats, {"x": x, "y": y}, 1)
    np.testing.assert_allclose(float(loss), float(half), rtol=1e-5)


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned hyperparameters."""
    expect = {
        "mamba2-2.7b": dict(n_layers=64, d_model=2560, vocab_size=50280,
                            ssm_state=128),
        "internlm2-1.8b": dict(n_layers=24, d_model=2048, n_heads=16,
                               n_kv_heads=8, d_ff=8192, vocab_size=92544),
        "musicgen-medium": dict(n_layers=48, d_model=1536, n_heads=24,
                                n_kv_heads=24, d_ff=6144, vocab_size=2048),
        "deepseek-v2-lite-16b": dict(n_layers=27, d_model=2048, n_heads=16,
                                     vocab_size=102400, n_experts=64,
                                     top_k=6, kv_lora_rank=512,
                                     n_shared_experts=2, moe_d_ff=1408),
        "h2o-danube-3-4b": dict(n_layers=24, d_model=3840, n_heads=32,
                                n_kv_heads=8, d_ff=10240, vocab_size=32000),
        "kimi-k2-1t-a32b": dict(n_layers=61, d_model=7168, n_heads=64,
                                n_kv_heads=8, vocab_size=163840,
                                n_experts=384, top_k=8, moe_d_ff=2048),
        "gemma3-27b": dict(n_layers=62, d_model=5376, n_heads=32,
                           n_kv_heads=16, d_ff=21504, vocab_size=262144),
        "stablelm-3b": dict(n_layers=32, d_model=2560, n_heads=32,
                            n_kv_heads=32, d_ff=6912, vocab_size=50304),
        "zamba2-1.2b": dict(n_layers=38, d_model=2048, n_heads=32,
                            n_kv_heads=32, d_ff=8192, vocab_size=32000,
                            ssm_state=64),
        "internvl2-1b": dict(n_layers=24, d_model=896, n_heads=14,
                             n_kv_heads=2, d_ff=4864, vocab_size=151655),
    }
    for arch, fields in expect.items():
        cfg = get_config(arch)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
    # gemma3 pattern is 5 local : 1 global
    g = get_config("gemma3-27b")
    assert g.block_pattern.count("attn") * 5 <= g.block_pattern.count("swa") + 5
    # kimi/deepseek first layer dense
    assert get_config("kimi-k2-1t-a32b").ffn_pattern[0] == "dense"
    assert get_config("deepseek-v2-lite-16b").ffn_pattern[0] == "dense"


def test_remat_and_policy_preserve_loss():
    """remat / remat_policy change memory/compute scheduling, never math."""
    cfg = make_reduced(get_config("gemma3-27b"))
    model = SplitModel(cfg)
    params = model.init(KEY)
    batch = _lm_batch(cfg)
    base, _ = model.full_loss(params, batch)
    for repl in (dict(remat=True), dict(remat=True, remat_policy="dots"),
                 dict(remat=True, scan_layers=True)):
        c2 = dataclasses.replace(cfg, **repl)
        l2, _ = SplitModel(c2).full_loss(params, batch)
        np.testing.assert_allclose(float(base), float(l2), rtol=1e-5), repl
