import jax
import pytest

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device; only launch/dryrun.py widens the mesh.

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
