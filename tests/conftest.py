import jax
import pytest

from hypothesis_compat import HAVE_HYPOTHESIS

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device; only launch/dryrun.py widens the mesh.

jax.config.update("jax_enable_x64", False)

if HAVE_HYPOTHESIS:
    # CI selects this with --hypothesis-profile=ci: no deadline (shared
    # runners stall), examples printed as reproducible blobs, and the
    # falsifying-example database kept under .hypothesis/ so the chaos
    # job can upload it as an artifact on failure.
    from hypothesis import settings as _hsettings

    _hsettings.register_profile("ci", deadline=None, print_blob=True)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
