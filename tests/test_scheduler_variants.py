"""Scheduler variants: paper median-matching vs beyond-paper min-time,
including the non-monotone-time regime where median matching loses.
Rounds are driven through the shared RoundDriver (CallableCost adapts a
plain t_of(cid, split) table)."""

from repro.core.driver import CallableCost, RoundDriver
from repro.core.scheduler import (FixedSplitScheduler, MinTimeScheduler,
                                  SlidingSplitScheduler)
from repro.core.split import SplitPlan


def _run(sched, devices, t_of, rounds=8):
    """devices: ids; t_of(cid, split). Returns post-warmup wall clock."""
    drv = RoundDriver(sched, CallableCost(t_of), devices)
    wall = 0.0
    for r in range(rounds):
        rec = drv.run_round(devices)
        if r >= sched.plan.k:            # §3.1 warm-up rounds excluded
            wall += rec.round_time
    return wall


def test_mintime_never_worse_than_median_monotone():
    """Monotone time-in-split (big-model regime): both schedulers find
    small splits for stragglers; mintime is at least as good."""
    plan = SplitPlan(n_units=10, split_points=(1, 3, 5))
    speed = {0: 8.0, 1: 2.0, 2: 1.0}
    t_of = lambda c, s: (s + 2) / speed[c]
    w_median = _run(SlidingSplitScheduler(plan), list(speed), t_of)
    w_min = _run(MinTimeScheduler(plan), list(speed), t_of)
    assert w_min <= w_median + 1e-9


def test_mintime_wins_when_argmin_straddles_median():
    """Median matching deliberately picks a split whose time is NEAR THE
    MEDIAN even when the device has a strictly faster option — min-time
    takes the faster option and wins the round wall-clock."""
    plan = SplitPlan(n_units=4, split_points=(1, 2, 3))
    # device 1's fastest split (1 -> 4.0) is BELOW the median (5.0), so
    # median matching sends it to split 2 (5.0) instead.
    T = {(0, 1): 4.8, (0, 2): 5.0, (0, 3): 5.2,
         (1, 1): 4.0, (1, 2): 5.0, (1, 3): 9.0}
    t_of = lambda c, s: T[(c, s)]
    w_median = _run(SlidingSplitScheduler(plan), [0, 1], t_of)
    w_min = _run(MinTimeScheduler(plan), [0, 1], t_of)
    assert w_min < w_median
    sched = MinTimeScheduler(plan)
    _run(sched, [0, 1], t_of, rounds=plan.k)
    assert sched.select([0, 1])[1] == 1     # the true argmin


def test_ema_tracks_drifting_device():
    plan = SplitPlan(n_units=4, split_points=(1, 2))
    sched = SlidingSplitScheduler(plan, ema=0.5)
    for t in (10.0, 2.0, 2.0, 2.0, 2.0):
        sched.observe(0, 1, t)
    assert sched.table.get(0, 1) < 3.0      # converged toward 2.0


def test_mintime_falls_back_to_smallest_for_unmeasured():
    """A client with no time-table entries (joined after warm-up) gets
    the smallest split — the safe choice for an unknown device."""
    plan = SplitPlan(n_units=6, split_points=(1, 2, 4))
    sched = MinTimeScheduler(plan)
    t_of = lambda c, s: (s + 1.0) * (c + 1.0)
    _run(sched, [0, 1], t_of, rounds=plan.k + 1)    # table for 0,1 only
    assert not sched.warming_up
    sel = sched.select([0, 1, 99])                  # 99 never measured
    assert sel[99] == plan.smallest()
    assert sel[0] in plan.split_points and sel[1] in plan.split_points
    # same fallback on the median-matching scheduler
    sched2 = SlidingSplitScheduler(plan)
    _run(sched2, [0, 1], t_of, rounds=plan.k + 1)
    assert sched2.select([0, 1, 99])[99] == plan.smallest()


def test_warmup_traverses_all_splits_once_per_cycle():
    """§3.1: the K warm-up rounds dispatch each candidate split exactly
    once (all clients share the split within a round) — observed through
    the driver's per-round split record."""
    plan = SplitPlan(n_units=10, split_points=(1, 3, 5))
    for cls in (SlidingSplitScheduler, MinTimeScheduler):
        sched = cls(plan)
        drv = RoundDriver(sched, CallableCost(lambda c, s: 1.0 + s),
                          [0, 1, 2])
        seen = []
        for r in range(plan.k):
            rec = drv.run_round([0, 1, 2])
            assert len(set(rec.splits.values())) == 1   # shared split
            seen.append(next(iter(rec.splits.values())))
        assert seen == list(plan.split_points)      # each exactly once
        assert len(seen) == plan.k
        assert not sched.warming_up                 # table is warm now


def test_fixed_scheduler_interface():
    plan = SplitPlan(n_units=4, split_points=(1, 2, 3))
    s = FixedSplitScheduler(plan, split=2)
    assert s.select([5])[5] == 2
    s.observe(5, 2, 1.0)
    s.end_round()
    assert not s.warming_up
