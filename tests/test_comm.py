"""repro.comm: int8 Pallas kernel numerics vs the jnp reference, codec
byte accounting, CommChannel metering, LinkTrace semantics, and the
end-to-end engine properties (int8 cuts accumulated comm >= 3.5x at
matched rounds with loss still decreasing; a trace-driven link changes
the sliding scheduler's split assignments vs the static link)."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import AUX_BYTES, CommChannel, LinkTrace, StaticLink, \
    get_codec, list_codecs, shared_link_finish_times
from repro.configs import CommConfig, get_config
from repro.core.simulation import make_device_grid
from repro.kernels.int8_quant.kernel import (int8_dequantize_pallas,
                                             int8_quantize_pallas)
from repro.kernels.int8_quant.ops import GROUP, int8_dequantize, \
    int8_quantize
from repro.kernels.int8_quant.ref import (int8_dequantize_ref,
                                          int8_quantize_ref)

KEY = jax.random.PRNGKey(11)


# ---------------------------------------------------------------------------
# int8 kernel pair vs jnp reference
# ---------------------------------------------------------------------------
Q_CASES = [
    (16, 256, 1.0),
    (7, 384, 5.0),        # non-multiple-of-8 rows
    (1024, 128, 0.1),
    (3, 1000, 2.0),       # non-128 lanes
]


@pytest.mark.parametrize("r,c,scale", Q_CASES)
def test_int8_pallas_matches_ref(r, c, scale):
    x = jax.random.normal(KEY, (r, c)) * scale
    qp, sp, zp = int8_quantize_pallas(x, interpret=True)
    qr, sr, zr = int8_quantize_ref(x)
    # identical math modulo float assoc: quantized codes within 1 step,
    # dequantized values within atol=1e-2 (the acceptance bound)
    assert np.abs(np.asarray(qp, np.int32)
                  - np.asarray(qr, np.int32)).max() <= 1
    xp = int8_dequantize_pallas(qp, sp, zp, interpret=True)
    xr = int8_dequantize_ref(qr, sr, zr)
    np.testing.assert_allclose(np.asarray(xp), np.asarray(xr), atol=1e-2)


def test_int8_roundtrip_error_bounded():
    """Affine per-group quantization: error <= scale/2 = range/(2*254)."""
    x = jax.random.normal(KEY, (64, 512)) * 3.0
    q, s, z, shape = int8_quantize(x)
    xr = int8_dequantize(q, s, z, shape)
    err = np.abs(np.asarray(xr - x))
    rng = float(x.max() - x.min())
    assert err.max() <= rng / 254.0 + 1e-6


def test_int8_arbitrary_rank_and_tail_group():
    for shape in [(5, 3, 7, 11), (130,), (2, GROUP + 1)]:
        x = jax.random.normal(KEY, shape)
        q, s, z, sh = int8_quantize(x)
        assert sh == shape and q.shape[1] <= GROUP
        xr = int8_dequantize(q, s, z, sh)
        assert xr.shape == shape
        assert float(jnp.max(jnp.abs(xr - x))) < 0.05


def test_int8_tail_group_error_bound_holds():
    """Regression: the tail group is edge-padded, not zero-padded —
    zero padding dragged an offset tail group's range toward 0 and blew
    the error ~50x past range/254."""
    x = 10.0 + jax.random.uniform(KEY, (300,)) * 0.1   # 300 % 256 != 0
    q, s, z, sh = int8_quantize(x)
    xr = int8_dequantize(q, s, z, sh)
    err = np.abs(np.asarray(xr - x))
    assert err.max() <= 0.1 / 254.0 + 1e-6             # per-group range


def test_int8_constant_input():
    """Zero-range rows must not divide by zero."""
    x = jnp.full((4, 256), 2.5)
    q, s, z, sh = int8_quantize(x)
    xr = int8_dequantize(q, s, z, sh)
    np.testing.assert_allclose(np.asarray(xr), 2.5, atol=1e-4)


# ---------------------------------------------------------------------------
# codecs
# ---------------------------------------------------------------------------
def test_codec_registry():
    assert set(list_codecs()) == {"fp32", "bf16", "fp16", "int8",
                                  "topk", "randk"}
    with pytest.raises(ValueError, match="zstd"):
        get_codec("zstd")


@pytest.mark.parametrize("name,bpv,tol", [
    ("fp32", 4.0, 0.0), ("bf16", 2.0, 0.05), ("fp16", 2.0, 1e-3),
    ("int8", 1.0, 0.05)])
def test_codec_roundtrip_and_bytes(name, bpv, tol):
    codec = get_codec(name)
    x = jax.random.normal(KEY, (8, 512))      # 4096 values, 16 groups
    out, nbytes = codec.roundtrip(x)
    assert out.dtype == x.dtype and out.shape == x.shape
    assert float(jnp.max(jnp.abs(out - x))) <= tol * 3.0 + 1e-9
    expected = x.size * bpv
    if name == "int8":
        expected += (x.size // GROUP) * 8.0
    assert nbytes == pytest.approx(expected)
    # analytic estimate agrees with the metered bytes
    assert codec.estimate_bytes(x.size, x.shape[-1]) \
        == pytest.approx(nbytes, rel=0.01)


# ---------------------------------------------------------------------------
# channel
# ---------------------------------------------------------------------------
def test_channel_meters_directions_and_rounds():
    ch = CommChannel(codec="int8", grad_codec="fp32")
    assert ch.feature_codec.name == "int8"
    assert ch.grad_codec.name == "fp32"
    h = jax.random.normal(KEY, (4, 256))
    feats = {"h": h, "aux": jnp.zeros((), jnp.float32)}
    rx = ch.uplink_features(7, feats)
    assert rx["h"].shape == h.shape
    up = 4 * 256 * 1.0 + 4 * 8.0 + AUX_BYTES
    assert ch.up_bytes == pytest.approx(up)
    ch.downlink_grads(7, {"h": h, "aux": jnp.zeros((), jnp.float32)})
    down = 4 * 256 * 4.0 + AUX_BYTES
    assert ch.down_bytes == pytest.approx(down)
    assert ch.round_payload(7) == pytest.approx(up + down)
    assert ch.round_payload(8) == 0.0
    ch.reset_round()
    assert ch.round_payload(7) == 0.0
    assert ch.total_bytes == pytest.approx(up + down)   # totals persist


def test_channel_default_grad_codec_follows_feature_codec():
    ch = CommChannel(codec="bf16")
    assert ch.grad_codec.name == "bf16"


def test_channel_per_direction_round_split():
    """The phase pipeline prices uplink (features) and downlink (dfx)
    separately; the split must sum to the combined round payload."""
    ch = CommChannel(codec="int8", grad_codec="fp32")
    h = jax.random.normal(KEY, (4, 256))
    ch.uplink_features(3, h)
    ch.downlink_grads(3, h)
    up, down = ch.round_payload_split(3)
    assert up == pytest.approx(4 * 256 * 1.0 + 4 * 8.0)
    assert down == pytest.approx(4 * 256 * 4.0)
    assert up + down == pytest.approx(ch.round_payload(3))
    assert ch.round_payload_split(99) == (0.0, 0.0)
    # the analytic per-direction estimates follow the same codecs
    n = 4 * 256
    assert ch.estimate_uplink_payload(n) + ch.estimate_downlink_payload(n) \
        == pytest.approx(ch.estimate_round_payload(n))
    assert ch.estimate_uplink_payload(n) < ch.estimate_downlink_payload(n)


def test_channel_round_split_reset_semantics():
    """Per-round meters are per-round: reset_round() zeroes both the
    payload and dispatch splits for every device, consecutive rounds
    meter independently, and re-metering the same device within one
    round accumulates (gated re-dispatch re-sends Wc)."""
    ch = CommChannel(codec="int8", dispatch_codec="int8")
    h = jax.random.normal(KEY, (4, 256))
    w = [np.ones((8, 8), np.float32)]
    # round 0: payload + model legs for device 3
    ch.uplink_features(3, h)
    ch.downlink_grads(3, h)
    ch.dispatch_leaves(3, w)
    ch.collect_leaves(3, w)
    up0, down0 = ch.round_payload_split(3)
    dd0, du0 = ch.round_dispatch_split(3)
    assert up0 > 0 and down0 > 0 and dd0 > 0 and du0 > 0
    # same-round re-dispatch accumulates, it does not overwrite
    ch.dispatch_leaves(3, w)
    assert ch.round_dispatch_split(3) == (pytest.approx(2 * dd0),
                                          pytest.approx(du0))
    ch.reset_round()
    assert ch.round_payload_split(3) == (0.0, 0.0)
    assert ch.round_dispatch_split(3) == (0.0, 0.0)
    assert ch.round_payload(3) == 0.0 and ch.round_dispatch(3) == 0.0
    # round 1: a fresh meter for a different device, 3 stays zero
    ch.uplink_features(5, h)
    ch.dispatch_leaves(5, w)
    assert ch.round_payload_split(5) == (pytest.approx(up0), 0.0)
    assert ch.round_dispatch_split(5) == (pytest.approx(dd0), 0.0)
    assert ch.round_payload_split(3) == (0.0, 0.0)
    # lifetime totals persist across the resets
    assert ch.total_bytes == pytest.approx(
        2 * up0 + down0 + 3 * dd0 + du0)


def test_channel_validates_delay_knobs():
    with pytest.raises(ValueError):
        CommChannel(latency=-0.1)
    with pytest.raises(ValueError):
        CommChannel(uplink_capacity=-1.0)
    ch = CommChannel(latency=0.5, uplink_capacity=1e6)
    assert ch.latency == 0.5 and ch.uplink_capacity == 1e6


# ---------------------------------------------------------------------------
# shared-uplink contention (fluid max-min fair schedule)
# ---------------------------------------------------------------------------
def test_shared_link_known_answers():
    # two equal jobs split the capacity: both take twice as long
    assert shared_link_finish_times(
        [(0.0, 100.0, 10.0), (0.0, 100.0, 10.0)], 10.0) \
        == pytest.approx([20.0, 20.0])
    # staggered arrivals: the first finishes alone, the second after it
    assert shared_link_finish_times(
        [(0.0, 100.0, 10.0), (10.0, 50.0, 10.0)], 10.0) \
        == pytest.approx([10.0, 15.0])
    # a slow device never blocks the fast one from the leftover capacity
    assert shared_link_finish_times(
        [(0.0, 100.0, 2.0), (0.0, 100.0, 100.0)], 10.0) \
        == pytest.approx([50.0, 12.5])
    # uncontended degenerates to arrival + size/rate; zero-size lands
    # on arrival
    assert shared_link_finish_times(
        [(1.0, 30.0, 10.0), (5.0, 0.0, 10.0)]) \
        == pytest.approx([4.0, 5.0])
    # a finisher frees its share mid-flight for the survivor
    fins = shared_link_finish_times(
        [(0.0, 50.0, 10.0), (0.0, 100.0, 10.0)], 10.0)
    # both at 5 B/s until t=10 (job0 done); job1 has 50 B left at 10 B/s
    assert fins == pytest.approx([10.0, 15.0])
    assert shared_link_finish_times([], 10.0) == []
    with pytest.raises(ValueError):
        shared_link_finish_times([(0.0, 1.0, 1.0)], 0.0)
    with pytest.raises(ValueError):
        shared_link_finish_times([(0.0, 1.0, 0.0)], 10.0)


# ---------------------------------------------------------------------------
# links
# ---------------------------------------------------------------------------
def test_link_trace_lookup_wrap_and_phase():
    tr = LinkTrace([0.0, 10.0, 20.0], [1.0, 0.25, 0.5], period=30.0,
                   per_device_phase=False)
    dev = make_device_grid(1, seed=0)[0]
    assert tr.rate(dev, 5.0) == pytest.approx(dev.rate)
    assert tr.rate(dev, 12.0) == pytest.approx(dev.rate * 0.25)
    assert tr.rate(dev, 29.0) == pytest.approx(dev.rate * 0.5)
    assert tr.rate(dev, 35.0) == pytest.approx(dev.rate)       # wraps
    # per-device phase decorrelates devices
    tr2 = LinkTrace([0.0, 10.0, 20.0], [1.0, 0.25, 0.5], period=30.0)
    d0, d1 = make_device_grid(2, seed=0)
    m0 = [tr2.rate(d0, t) / d0.rate for t in np.linspace(0, 29, 30)]
    m1 = [tr2.rate(d1, t) / d1.rate for t in np.linspace(0, 29, 30)]
    assert m0 != m1


def test_link_trace_default_period_keeps_last_segment():
    """Regression: with no explicit period the final multiplier must
    still get a non-empty segment (period == times[-1] silently dropped
    it)."""
    tr = LinkTrace([0.0, 50.0], [1.0, 0.1], per_device_phase=False)
    dev = make_device_grid(1, seed=0)[0]
    assert tr.period == pytest.approx(100.0)
    assert tr.rate(dev, 60.0) == pytest.approx(dev.rate * 0.1)
    with pytest.raises(ValueError):
        LinkTrace([0.0, 50.0], [1.0, 0.1], period=50.0)   # zero-length


def test_link_trace_from_file(tmp_path):
    spec = {"times": [0.0, 50.0], "multipliers": [1.0, 0.1],
            "period": 100.0}
    p = tmp_path / "trace.json"
    p.write_text(json.dumps(spec))
    tr = LinkTrace.from_file(str(p), per_device_phase=False)
    dev = make_device_grid(1, seed=0)[0]
    assert tr.rate(dev, 60.0) == pytest.approx(dev.rate * 0.1)


def test_static_link_reproduces_table1():
    link = StaticLink()
    for d in make_device_grid(9, seed=0):
        assert link.rate(d, 0.0) == d.rate
        assert link.rate(d, 1e6) == d.rate


# ---------------------------------------------------------------------------
# engine end-to-end: codec cuts comm, training still learns
# ---------------------------------------------------------------------------
def _engine(codec, plan, fed, model, rounds=3):
    from repro.core.engine import EngineConfig, S2FLEngine
    ecfg = EngineConfig(mode="s2fl", rounds=rounds, clients_per_round=4,
                        batch_size=16, group_size=2,
                        comm=CommConfig(codec=codec))
    eng = S2FLEngine(model, fed, ecfg, plan=plan)
    eng.run(rounds=rounds)
    return eng


@pytest.mark.slow
def test_engine_int8_cuts_comm_while_learning():
    """Acceptance: codec='int8' cuts accumulated comm >= 3.5x vs fp32 at
    matched rounds, and the training loss still decreases. Shallow split
    (the Fig.-3 regime: tiny |Wc|, feature exchange dominates)."""
    from repro.core.split import SplitPlan
    from repro.data.partition import federate
    from repro.data.synthetic import make_image_dataset
    from repro.models import SplitModel

    ds = make_image_dataset(400, seed=0)
    fed = federate(ds, 6, alpha=0.3, seed=0)
    model = SplitModel(get_config("resnet8"))
    plan = SplitPlan(n_units=4, split_points=(1,))

    e32 = _engine("fp32", plan, fed, model)
    e8 = _engine("int8", plan, fed, model)
    assert len(e8.history) == len(e32.history) == 3
    assert e32.comm / e8.comm >= 3.5
    losses = [h["loss"] for h in e8.history]
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]              # still training
    # fp32/static reproduces the seed semantics: finite too
    assert np.isfinite([h["loss"] for h in e32.history]).all()


# ---------------------------------------------------------------------------
# trace-driven link changes the sliding scheduler's assignments
# ---------------------------------------------------------------------------
def test_trace_link_changes_scheduler_assignments():
    """Acceptance: under a fading trace the client time table sees
    different Eq.-1 times, so post-warmup split assignments differ from
    the static link's. Pure Eq.-1 simulation on VGG16 costs, driven by
    the shared RoundDriver."""
    from repro.core.driver import AnalyticCost, RoundDriver
    from repro.core.scheduler import SlidingSplitScheduler
    from repro.core.split import default_plan
    from repro.models import SplitModel
    from repro.utils.flops import split_costs

    model = SplitModel(get_config("vgg16"))
    plan = default_plan(model.n_units, k=3)
    costs = {s: split_costs(model, s) for s in plan.split_points}
    devices = make_device_grid(9, seed=0)

    def final_assignment(link):
        ch = CommChannel(codec="fp32", link=link)
        sched = SlidingSplitScheduler(plan)
        drv = RoundDriver(sched, AnalyticCost(ch, costs, p=32), devices)
        for r in range(plan.k + 3):
            drv.run_round(devices)
        return sched.select([d.cid for d in devices])

    static = final_assignment(StaticLink())
    faded = final_assignment(LinkTrace.fading(
        n_segments=6, period=300.0, lo=0.02, hi=1.0, seed=5))
    assert static != faded


# ---------------------------------------------------------------------------
# satellite regression: loss reporting edge cases
# ---------------------------------------------------------------------------
def test_sfl_round_zero_local_steps_no_crash():
    from repro.core.engine import EngineConfig, S2FLEngine
    from repro.data.partition import federate
    from repro.data.synthetic import make_image_dataset
    from repro.models import SplitModel

    ds = make_image_dataset(120, seed=0)
    fed = federate(ds, 4, alpha=0.5, seed=0)
    model = SplitModel(get_config("resnet8"))
    ecfg = EngineConfig(mode="s2fl", rounds=1, clients_per_round=3,
                        batch_size=8, local_steps=0)
    eng = S2FLEngine(model, fed, ecfg)
    rec = eng.run_round()                      # seed crashed: unbound loss
    assert np.isnan(rec["loss"])
    assert rec["clock"] > 0                    # dispatch still costs time


def test_fedavg_reports_mean_loss_over_clients():
    from repro.core.engine import EngineConfig, S2FLEngine
    from repro.data.partition import federate
    from repro.data.synthetic import make_image_dataset
    from repro.models import SplitModel

    ds = make_image_dataset(120, seed=0)
    fed = federate(ds, 4, alpha=0.5, seed=0)
    model = SplitModel(get_config("resnet8"))
    ecfg = EngineConfig(mode="fedavg", rounds=1, clients_per_round=3,
                        batch_size=8)
    eng = S2FLEngine(model, fed, ecfg)
    per_client = iter([1.0, 3.0, 8.0])
    eng._fedavg_step = lambda p, b: (p, next(per_client))
    rec = eng.run_round()
    assert rec["loss"] == pytest.approx(4.0)   # mean, not the last (8.0)
