"""Observability subsystem (src/repro/observe): with the recorder off
the pipeline goldens are bit-exact (the zero-overhead contract); with
it on the timeline is unperturbed and the per-window critical-path
decomposition reconstructs every window's makespan within 1e-6 relative
tolerance — property-checked over randomized (uplink, downlink, server
slots, latency-dist, gating, mode) regimes. Plus: span-field sanity,
Chrome trace-event structure, recorder JSON round-trip, the metrics
registry / JSONL sink units, and the engine integration."""
import json
import math

import numpy as np
import pytest

from repro.comm import CommChannel, StaticLink
from repro.core.driver import AnalyticCost, RoundDriver
from repro.core.scheduler import SlidingSplitScheduler
from repro.core.simulation import make_device_grid
from repro.observe import (Histogram, JsonlSink, MetricsRegistry,
                           NullRecorder, Recorder, chrome_trace,
                           load_recorder, summarize,
                           verify_reconstruction, window_breakdown,
                           write_chrome_trace)
from tests.test_driver import (COSTS, GOLDEN_COMM, GOLDEN_PIPE_CLOCK,
                               P, PLAN)


def _drive(recorder=None, mode="semi_async", rounds=10, seed=0,
           n_devices=12, per_round=5, pipeline=True, staleness_cap=1,
           quorum=0.5, latency=0.0, latency_dist="constant",
           uplink_capacity=0.0, downlink_capacity=0.0,
           server_concurrency=0, gate_redispatch=False, flush=True):
    """The tests/test_driver.py golden setup, with a recorder slot."""
    devices = make_device_grid(n_devices, seed=seed)
    ch = CommChannel(codec="fp32", link=StaticLink(), latency=latency,
                     latency_dist=latency_dist, latency_seed=seed,
                     uplink_capacity=uplink_capacity,
                     downlink_capacity=downlink_capacity)
    drv = RoundDriver(SlidingSplitScheduler(PLAN),
                      AnalyticCost(ch, COSTS, p=P), devices, mode=mode,
                      staleness_cap=staleness_cap, quorum=quorum,
                      pipeline=pipeline,
                      server_concurrency=server_concurrency,
                      gate_redispatch=gate_redispatch, recorder=recorder)
    rng = np.random.default_rng(seed)
    for _ in range(rounds):
        drv.run_round(rng.choice(devices, size=per_round, replace=False))
    if flush:
        drv.flush()
    return drv


# ---------------------------------------------------------------------------
# the overhead contract: default-off recorder leaves the goldens alone
# ---------------------------------------------------------------------------
def test_pipeline_goldens_unchanged_without_recorder():
    drv = _drive(recorder=None)
    assert drv.clock == pytest.approx(GOLDEN_PIPE_CLOCK, rel=1e-12)
    assert drv.comm == pytest.approx(GOLDEN_COMM, rel=1e-12)


def test_null_recorder_is_the_protocol_and_a_noop():
    rec = NullRecorder()
    assert not rec.enabled
    # every hook is callable and returns nothing
    rec.flight(0, cid=1)
    rec.atomic("k", 0, [1], 0.0, 1.0)
    rec.window(0, 0.0, 1.0, {}, 0)
    rec.gauge("g", 0.0, 1.0)
    rec.count("c")
    drv = _drive(recorder=rec)
    assert drv.clock == pytest.approx(GOLDEN_PIPE_CLOCK, rel=1e-12)
    assert drv.comm == pytest.approx(GOLDEN_COMM, rel=1e-12)


def test_recording_does_not_perturb_the_timeline():
    """The recorder only observes: the golden pipelined clock/comm are
    bit-identical with a live Recorder injected."""
    rec = Recorder()
    drv = _drive(recorder=rec)
    assert drv.clock == pytest.approx(GOLDEN_PIPE_CLOCK, rel=1e-12)
    assert drv.comm == pytest.approx(GOLDEN_COMM, rel=1e-12)
    assert rec.flights and rec.windows
    assert rec.counters["driver.rounds"] == 10


# ---------------------------------------------------------------------------
# span records
# ---------------------------------------------------------------------------
def test_flight_spans_are_ordered_and_complete():
    rec = Recorder()
    _drive(recorder=rec, uplink_capacity=5e5, downlink_capacity=5e5,
           server_concurrency=2, latency=0.01)
    assert len(rec.flights) == 10 * 5       # one per device-round
    for fl in rec.flights.values():
        for f in ("cid", "round", "key", "dispatch", "up_start",
                  "up_end", "srv_start", "srv_end", "dl_xfer_end",
                  "dl_end", "up_bytes", "up_rate", "t_pre"):
            assert f in fl, fl
        eps = 1e-9
        assert fl["dispatch"] <= fl["up_start"] + eps
        assert fl["up_start"] <= fl["up_end"] + eps
        assert fl["up_end"] <= fl["srv_start"] + eps   # FIFO queue wait
        assert fl["srv_start"] <= fl["srv_end"] + eps
        assert fl["srv_end"] <= fl["dl_xfer_end"] + eps
        assert fl["dl_xfer_end"] <= fl["dl_end"] + eps
        # the uplink flow can't beat the device's own rate
        assert fl["up_end"] - fl["up_start"] \
            >= fl["up_bytes"] / fl["up_rate"] - eps


def test_window_records_cover_the_run():
    rec = Recorder()
    drv = _drive(recorder=rec)
    rounds = [w for w in rec.windows if w["kind"] == "round"]
    flushes = [w for w in rec.windows if w["kind"] == "flush"]
    assert len(rounds) == 10 and len(flushes) == 1
    # windows tile the timeline: each opens at the previous close
    for a, b in zip(rec.windows, rec.windows[1:]):
        assert b["t0"] == pytest.approx(a["t_close"])
    assert rec.windows[-1]["t_close"] == pytest.approx(drv.clock)


def test_atomic_records_for_non_pipelined_rounds():
    rec = Recorder()
    drv = _drive(recorder=rec, pipeline=False, mode="sync", flush=False)
    assert not rec.flights
    assert len(rec.atomics) == 10 * 5
    err = verify_reconstruction(rec)
    assert err <= 1e-9
    rows = window_breakdown(rec)
    assert all("atomic" in r["components"] for r in rows)
    assert rows[-1]["t_close"] == pytest.approx(drv.clock)


def test_gauges_sampled_per_round():
    rec = Recorder()
    _drive(recorder=rec, uplink_capacity=5e5, server_concurrency=1)
    for g in ("server.queue_depth", "downloads.in_flight",
              "window.pending", "uplink.live_flows",
              "uplink.utilization"):
        assert g in rec.gauges, sorted(rec.gauges)
        assert len(rec.gauges[g]) == 10
    # utilization is a fraction of capacity
    assert all(0.0 <= v <= 1.0 + 1e-9
               for _, v in rec.gauges["uplink.utilization"])
    assert all(v >= 0 for _, v in rec.gauges["server.queue_depth"])


# ---------------------------------------------------------------------------
# the acceptance property: critical-path reconstruction over randomized
# resource regimes (seeded numpy — runs without hypothesis)
# ---------------------------------------------------------------------------
def test_critical_path_reconstructs_makespan_over_random_regimes():
    rng = np.random.default_rng(42)
    checked = 0
    for trial in range(12):
        kw = dict(
            seed=int(rng.integers(0, 1000)),
            rounds=int(rng.integers(4, 9)),
            per_round=int(rng.integers(3, 7)),
            mode=("semi_async", "sync")[int(rng.integers(0, 2))],
            quorum=float(rng.uniform(0.3, 1.0)),
            staleness_cap=int(rng.integers(1, 4)),
            uplink_capacity=(0.0, 2e5, 8e5)[int(rng.integers(0, 3))],
            downlink_capacity=(0.0, 2e5, 8e5)[int(rng.integers(0, 3))],
            server_concurrency=int(rng.integers(0, 4)),
            gate_redispatch=bool(rng.integers(0, 2)),
            latency=float(rng.uniform(0.0, 0.05)),
            latency_dist=("constant", "uniform",
                          "lognormal", "exp")[int(rng.integers(0, 4))],
        )
        rec = Recorder()
        drv = _drive(recorder=rec, **kw)
        err = verify_reconstruction(rec, rel=1e-6)
        assert err <= 1e-6, (kw, err)
        rows = window_breakdown(rec)
        # every advancing window is attributed to a concrete event
        for row in rows:
            if row["makespan"] > 1e-9:
                assert "unattributed" not in row["components"], (kw, row)
                checked += 1
        assert rows[-1]["t_close"] == pytest.approx(drv.clock)
    assert checked > 40          # the property actually bit


def test_summarize_attributes_stragglers():
    rec = Recorder()
    _drive(recorder=rec, uplink_capacity=3e5, downlink_capacity=3e5,
           server_concurrency=2, gate_redispatch=True, latency=0.01,
           latency_dist="uniform")
    s = summarize(rec)
    assert s["windows"] == len(rec.windows)
    assert s["max_reconstruction_err"] <= 1e-6
    # fractions sum to 1 over the attributed makespan
    assert sum(s["fractions"].values()) == pytest.approx(1.0)
    assert s["top_straggler"] is not None
    assert s["stragglers"][s["top_straggler"]] >= 1
    # straggler cids are real devices
    cids = {d.cid for d in make_device_grid(12, seed=0)}
    assert set(s["stragglers"]) <= cids


# ---------------------------------------------------------------------------
# export + persistence
# ---------------------------------------------------------------------------
def test_chrome_trace_structure_and_roundtrip(tmp_path):
    rec = Recorder()
    _drive(recorder=rec, uplink_capacity=5e5, downlink_capacity=5e5,
           server_concurrency=2, latency=0.01)
    doc = chrome_trace(rec)
    assert isinstance(doc["traceEvents"], list) and doc["traceEvents"]
    names = {e["args"]["name"] for e in doc["traceEvents"]
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert {"devices", "uplink", "server", "downlink"} <= names
    for e in doc["traceEvents"]:
        assert e["ph"] in ("X", "M", "C")
        if e["ph"] == "X":
            assert math.isfinite(e["ts"]) and e["dur"] >= 0.0
    # complete spans exist on every resource track
    pids = {e["pid"] for e in doc["traceEvents"] if e["ph"] == "X"}
    assert {1, 2, 3, 4} <= pids
    # the whole document is valid JSON with the recorder dump embedded
    path = tmp_path / "trace.json"
    write_chrome_trace(rec, str(path))
    rec2 = load_recorder(str(path))
    assert len(rec2.flights) == len(rec.flights)
    assert len(rec2.windows) == len(rec.windows)
    assert rec2.counters == pytest.approx(rec.counters)
    # the round-trip preserves the critical-path math exactly
    a = [r["components"] for r in window_breakdown(rec)]
    b = [r["components"] for r in window_breakdown(rec2)]
    assert a == b


def test_recorder_json_tuple_keys_survive():
    rec = Recorder()
    rec.flight(0, cid=3, round=0, key=(0, "g"), dispatch=0.0, t_pre=1.0,
               up_start=1.0, up_bytes=8.0, up_rate=8.0, up_end=2.0,
               srv_start=2.0, srv_end=3.0, dl_xfer_end=3.5, dl_end=4.0)
    rec.atomic((1, "h"), 0, [4], 0.0, 2.0)
    rec.window(0, 0.0, 4.0, {(0, "g"): 0, (1, "h"): 0}, 0)
    rec2 = Recorder.from_json(json.loads(json.dumps(rec.to_json())))
    (w,) = rec2.windows
    assert set(w["committed"]) == {(0, "g"), (1, "h")}
    assert rec2.flights[0]["key"] == (0, "g")
    assert rec2.atomics[0]["key"] == (1, "h")


# ---------------------------------------------------------------------------
# metrics registry + streaming sink
# ---------------------------------------------------------------------------
def test_metrics_registry_counters_gauges_histograms():
    m = MetricsRegistry()
    m.inc("a")
    m.inc("a", 2.5)
    m.set_gauge("g", 7.0, t=1.5)
    for v in (1.0, 2.0, 3.0, 0.0):
        m.observe("h", v)
    assert m.counter("a") == pytest.approx(3.5)
    assert m.counter("missing") == 0.0
    assert m.gauge("g") == (7.0, 1.5)
    assert m.gauge("missing") is None
    snap = m.snapshot()
    h = snap["histograms"]["h"]
    assert h["count"] == 4 and h["min"] == 0.0 and h["max"] == 3.0
    assert h["mean"] == pytest.approx(1.5)
    assert h["buckets"]["-inf"] == 1      # the zero landed underflow
    assert sum(h["buckets"].values()) == 4
    json.dumps(snap)                      # snapshot is JSON-safe


def test_recorder_forwards_into_metrics_registry():
    m = MetricsRegistry()
    rec = Recorder(metrics=m)
    _drive(recorder=rec, rounds=4)
    assert m.counter("driver.rounds") == 4
    assert m.counter("driver.rounds") == rec.counters["driver.rounds"]
    g = m.gauge("window.pending")
    assert g is not None and g[0] == rec.gauges["window.pending"][-1][1]


def test_jsonl_sink_streams_one_object_per_line(tmp_path):
    path = tmp_path / "metrics.jsonl"
    with JsonlSink(str(path)) as sink:
        sink.emit({"round": 0, "x": 1.5})
        sink.emit({"round": 1, "x": 2.5})
        assert sink.emitted == 2
        # per-record flush: both lines are on disk before close
        lines = path.read_text().splitlines()
        assert len(lines) == 2
    recs = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert recs[1] == {"round": 1, "x": 2.5}
    sink.close()                          # idempotent


def test_histogram_power_of_two_buckets():
    h = Histogram()
    for v in (1.0, 1.5, 2.0, 4.0, 100.0):
        h.observe(v)
    d = h.to_dict()
    assert d["buckets"]["0"] == 2         # [1, 2): 1.0, 1.5
    assert d["buckets"]["1"] == 1         # [2, 4): 2.0
    assert d["buckets"]["2"] == 1         # [4, 8): 4.0
    assert d["buckets"]["6"] == 1         # [64, 128): 100.0


# ---------------------------------------------------------------------------
# channel wire counters
# ---------------------------------------------------------------------------
def test_channel_counts_messages_and_bytes_per_direction():
    import jax.numpy as jnp
    ch = CommChannel(codec="int8", dispatch_codec="int8")
    rec = Recorder()
    ch.recorder = rec
    x = jnp.ones((4, 16), jnp.float32)
    ch.uplink_features(0, x)
    ch.uplink_features(1, x)
    ch.downlink_grads(0, x)
    ch.dispatch_leaves(0, [np.ones((3, 3), np.float32)])
    ch.collect_leaves(0, [np.ones((3, 3), np.float32)])
    assert rec.counters["comm.up.msgs"] == 2
    assert rec.counters["comm.down.msgs"] == 1
    assert rec.counters["comm.disp_down.msgs"] == 1
    assert rec.counters["comm.disp_up.msgs"] == 1
    assert rec.counters["comm.up.bytes"] \
        == pytest.approx(2 * ch._round_up[0])
    assert rec.counters["comm.up.bytes"] + rec.counters["comm.down.bytes"] \
        == pytest.approx(ch.up_bytes + ch.down_bytes)


# ---------------------------------------------------------------------------
# engine integration (training-heavy -> slow)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_engine_with_recorder_traces_and_reconstructs(tmp_path):
    from repro.configs.base import CommConfig, DriverConfig
    from repro.core.engine import EngineConfig, S2FLEngine
    from repro.data.partition import federate
    from repro.data.synthetic import make_image_dataset
    from repro.models import SplitModel
    from repro.configs import get_config

    fed = federate(make_image_dataset(200, seed=0), 4, alpha=0.3, seed=0)
    model = SplitModel(get_config("resnet8"))
    m = MetricsRegistry()
    rec = Recorder(metrics=m)
    ecfg = EngineConfig(
        mode="s2fl", rounds=3, clients_per_round=3, batch_size=16,
        comm=CommConfig(latency=0.01, uplink_capacity=2.0e5,
                        downlink_capacity=2.0e5),
        driver=DriverConfig(exec_mode="semi_async", pipeline=True,
                            server_concurrency=2))
    eng = S2FLEngine(model, fed, ecfg, recorder=rec)
    seen = []
    eng.run(on_round=seen.append)
    assert len(seen) == 3
    assert rec.flights and rec.windows
    assert verify_reconstruction(rec) <= 1e-6
    assert m.counter("comm.up.msgs") > 0          # channel hooks fired
    path = tmp_path / "engine_trace.json"
    write_chrome_trace(rec, str(path))
    assert load_recorder(str(path)).windows
