"""MoE dispatch semantics: sort-based ranking == first-come-first-served
token order; shard-local dispatch == global dispatch when nothing drops;
capacity dropping works."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.configs import get_config, make_reduced
from repro.models import moe as moe_mod
from repro.models.layers import mlp
from repro.models.params import init_params

KEY = jax.random.PRNGKey(0)


def _setup(**repl):
    cfg = make_reduced(get_config("deepseek-v2-lite-16b"))
    cfg = dataclasses.replace(cfg, **repl) if repl else cfg
    p = init_params(moe_mod.moe_defs(cfg), KEY, "float32")
    return cfg, p


def test_dispatch_matches_dense_reference():
    cfg, p = _setup()
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model)) * 0.5
    out, aux = moe_mod.moe_apply(cfg, p, x)
    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ p["router"]
    gates = jax.nn.softmax(logits, -1)
    topw, topi = jax.lax.top_k(gates, cfg.top_k)
    topw = topw / topw.sum(-1, keepdims=True)

    def ffn_e(e, v):
        g = jax.nn.silu(v @ p["w_gate"][e])
        u = v @ p["w_up"][e]
        return (g * u) @ p["w_down"][e]

    ref = jnp.zeros_like(xt)
    for j in range(cfg.top_k):
        ref += topw[:, j:j + 1] * jax.vmap(ffn_e)(topi[:, j], xt)
    ref = ref + mlp(p["shared"], xt, cfg.act)
    np.testing.assert_allclose(np.asarray(out.reshape(-1, cfg.d_model)),
                               np.asarray(ref), atol=5e-4)


def test_shard_local_matches_global_when_no_drops():
    cfg, p = _setup()
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 16, cfg.d_model)) * 0.5
    out_g, _ = moe_mod.moe_apply(cfg, p, x)
    cfg_s = dataclasses.replace(cfg, moe_dispatch_shards=4)
    out_s, _ = moe_mod.moe_apply(cfg_s, p, x)
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_s),
                               atol=5e-4)


def test_capacity_drops_zero_contribution():
    """With capacity 0 < C << T, dropped tokens contribute only the
    shared-expert output."""
    cfg, p = _setup()
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 64, cfg.d_model)) * 0.5
    out_tight, _ = moe_mod.moe_apply(cfg, p, x, capacity_factor=0.05)
    out_loose, _ = moe_mod.moe_apply(cfg, p, x, capacity_factor=4.0)
    # tight capacity must differ (tokens dropped)...
    assert float(jnp.abs(out_tight - out_loose).max()) > 1e-4
    # ...but stay finite
    assert np.isfinite(np.asarray(out_tight)).all()


@given(st.integers(0, 10**6))
@settings(max_examples=15, deadline=None)
def test_sort_ranking_is_token_order(seed):
    """Property: positions within each expert are 0..count-1 assigned in
    increasing token order (FCFS — what capacity dropping relies on)."""
    rng = np.random.default_rng(seed)
    E, N = 5, 64
    flat_e = jnp.asarray(rng.integers(0, E, size=N), jnp.int32)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E))
    rank_sorted = jnp.arange(N) - starts[sorted_e]
    pos = np.asarray(jnp.zeros((N,), jnp.int32).at[order].set(rank_sorted))
    fe = np.asarray(flat_e)
    for e in range(E):
        idx = np.flatnonzero(fe == e)
        assert pos[idx].tolist() == list(range(len(idx)))
