"""Sparsification + error-feedback compression (repro.comm).

Covers: the sparse codec wire format (index+value bytes, exact metering
vs the analytic estimate, top-k really keeps the largest magnitudes),
the channel's residual accumulators (the EF telescoping identity
``sum(delivered) = sum(sent) - final_residual`` as a hypothesis
property; residual reset on shape change; randk's unbiasedness scaling
disabled under feedback), dispatch-leg compression through the engine
(the 2|Wc| legs metered exactly, comm shrinks, training still learns),
the QSGD-style compressed-FedAvg baseline, and the bit-exactness
goldens: ``codec=fp32, error_feedback=False`` reproduces the pre-PR
engine's clock / comm / parameters EXACTLY (constants captured from the
engine before this PR's compression layer landed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.comm import CommChannel, get_codec
from repro.comm.codecs import (INDEX_BYTES, SPARSE_HEADER_BYTES,
                               RandomKCodec, TopKCodec)
from repro.configs import CommConfig, get_config

KEY = jax.random.PRNGKey(3)


# ---------------------------------------------------------------------------
# sparse codecs: wire format + selection semantics
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["topk", "randk"])
def test_sparse_codec_bytes_and_estimate(name):
    codec = get_codec(name, topk_frac=0.1)
    x = jax.random.normal(KEY, (8, 512))
    out, nbytes = codec.roundtrip(x)
    assert out.shape == x.shape and out.dtype == x.dtype
    k = int(np.ceil(0.1 * x.size))
    assert nbytes == k * (4.0 + INDEX_BYTES) + SPARSE_HEADER_BYTES
    assert codec.estimate_bytes(x.size) == pytest.approx(nbytes)
    # a frac-0.1 sparsifier is cheaper on the wire than int8 and fp32
    assert nbytes < get_codec("int8").estimate_bytes(x.size) \
        < get_codec("fp32").estimate_bytes(x.size)


def test_topk_keeps_largest_magnitudes():
    x = jnp.asarray([[0.1, -5.0, 0.2, 3.0, -0.3, 0.01, 2.0, -0.02]])
    codec = TopKCodec(frac=3 / 8)
    out, _ = codec.roundtrip(x)
    np.testing.assert_allclose(
        np.asarray(out), [[0.0, -5.0, 0.0, 3.0, 0.0, 0.0, 2.0, 0.0]])


def test_randk_unbiased_scaling_and_determinism():
    x = jax.random.normal(KEY, (64, 64))
    c1 = RandomKCodec(frac=0.25, seed=5)
    c2 = RandomKCodec(frac=0.25, seed=5)
    y1, _ = c1.roundtrip(x)
    y2, _ = c2.roundtrip(x)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    # kept entries are scaled by n/k = 4 (unbiased estimator)
    nz = np.asarray(y1)[np.asarray(y1) != 0.0]
    flat = np.asarray(x).reshape(-1)
    assert all(any(np.isclose(v, 4.0 * f) for f in flat) for v in nz[:8])
    # E[decode] ~ x: the mean over many draws approaches the input
    acc = np.zeros(x.shape)
    for i in range(40):
        acc += np.asarray(RandomKCodec(frac=0.25, seed=i).roundtrip(x)[0])
    assert np.abs(acc / 40 - np.asarray(x)).mean() \
        < 0.5 * np.abs(np.asarray(x)).mean()


def test_get_codec_unknown_raises_valueerror_naming_known():
    with pytest.raises(ValueError) as ei:
        get_codec("zstd")
    msg = str(ei.value)
    assert "zstd" in msg and "topk" in msg and "fp32" in msg
    with pytest.raises(ValueError):
        get_codec("topk", topk_frac=0.0)
    with pytest.raises(ValueError):
        get_codec("topk", topk_frac=1.5)


# ---------------------------------------------------------------------------
# error-feedback accumulators on the channel
# ---------------------------------------------------------------------------
def _ef_identity_error(codec, rounds, shape, frac, seed):
    """max |sum(sent) - sum(delivered) - residual| over elements."""
    ch = CommChannel(codec=codec, error_feedback=True, topk_frac=frac)
    sent = np.zeros(shape)
    got = np.zeros(shape)
    for r in range(rounds):
        x = jax.random.normal(jax.random.PRNGKey(seed * 97 + r), shape)
        rx = ch.uplink_features(0, x)
        sent += np.asarray(x, np.float64)
        got += np.asarray(rx, np.float64)
    res = np.asarray(ch._residuals[("up", 0)], np.float64)
    return float(np.abs(sent - got - res).max()), sent, got, res


@settings(max_examples=25, deadline=None)
@given(codec=st.sampled_from(["topk", "randk", "int8", "bf16"]),
       rounds=st.integers(min_value=2, max_value=10),
       rows=st.integers(min_value=1, max_value=6),
       cols=st.sampled_from([32, 257, 512]),
       frac=st.floats(min_value=0.05, max_value=0.5),
       seed=st.integers(min_value=0, max_value=10 ** 6))
def test_ef_transmitted_sum_telescopes(codec, rounds, rows, cols, frac,
                                       seed):
    """The EF recursion y_t = (x_t + e_{t-1}) - e_t telescopes: the sum
    of delivered tensors equals the sum of inputs minus exactly the
    final residual — compressed-with-feedback updates summed over
    rounds converge to the uncompressed sum up to one residual."""
    err, sent, got, res = _ef_identity_error(codec, rounds, (rows, cols),
                                             frac, seed)
    # float32 round-trips accumulate ~1e-6-scale noise per round
    assert err <= 5e-5 * max(1.0, np.abs(sent).max())
    # ...and the residual is bounded (the compressor is a contraction
    # under feedback), so the cumulative sums stay within tolerance
    assert np.abs(res).max() <= np.abs(sent).max() + 10.0


def test_ef_identity_concrete():
    """Shim-proof concrete instance of the property above."""
    err, sent, _, res = _ef_identity_error("topk", 8, (4, 256), 0.1, 1)
    assert err <= 5e-5 * np.abs(sent).max()
    assert np.abs(res).sum() > 0.0          # top-k really dropped mass


def test_ef_reduces_cumulative_error_for_sparsifiers():
    """Feedback re-injects dropped mass, so the cumulative-sum error
    after T rounds is smaller than the feedback-free drift."""
    shape, T = (4, 256), 10
    for codec in ("topk", "int8"):
        drift = {}
        for ef in (False, True):
            ch = CommChannel(codec=codec, error_feedback=ef,
                             topk_frac=0.1)
            diff = np.zeros(shape)
            for r in range(T):
                x = jax.random.normal(jax.random.PRNGKey(r), shape)
                rx = ch.uplink_features(0, x)
                diff += np.asarray(x, np.float64) \
                    - np.asarray(rx, np.float64)
            drift[ef] = float(np.linalg.norm(diff))
        assert drift[True] < drift[False], codec


def test_ef_residual_resets_on_shape_change():
    ch = CommChannel(codec="topk", error_feedback=True, topk_frac=0.1)
    ch.uplink_features(0, jax.random.normal(KEY, (4, 256)))
    assert ch._residuals[("up", 0)].shape == (4, 256)
    # a re-split changes the cut-tensor shape: stale residual ignored
    x2 = jax.random.normal(KEY, (2, 128))
    rx = ch.uplink_features(0, x2)
    assert rx.shape == x2.shape
    assert ch._residuals[("up", 0)].shape == (2, 128)
    ch.reset_feedback()
    assert ch.residual_norm() == 0.0


def test_ef_randk_scaling_disabled_under_feedback():
    """The n/k-scaled rand-k operator is not a contraction and diverges
    under feedback — the channel must construct it unscaled."""
    ch = CommChannel(codec="randk", error_feedback=True)
    assert ch.feature_codec.unbiased is False
    assert CommChannel(codec="randk").feature_codec.unbiased is True


def test_ef_off_is_stateless():
    ch = CommChannel(codec="topk", topk_frac=0.1)
    ch.uplink_features(0, jax.random.normal(KEY, (4, 256)))
    assert ch._residuals == {} and ch.residual_norm() == 0.0


# ---------------------------------------------------------------------------
# engine goldens: fp32 / no-feedback is bit-exact with the pre-PR engine
# ---------------------------------------------------------------------------
# Captured from the engine at the commit BEFORE the compression layer
# (sparsifiers, error feedback, dispatch codec) landed: resnet8 S²FL,
# 240 samples / 6 clients / alpha=0.3 / seed 0, 3 rounds of 4 clients,
# batch 16, group 2, default plan; FedAvg same data, 2 rounds.
# Param sums / loss tails are environment-sensitive at the last float
# digits (XLA version / CPU instruction selection), so the constants are
# recaptured by re-running the pre-compression commit (30d2ac9) in the
# CURRENT environment — the invariant tested is engine-vs-engine
# bit-exactness, not stability of XLA numerics across toolchains.
GOLDEN_S2FL = dict(clock=1.67794774976, comm=21778016.0,
                   param_sum=246.27124887085165,
                   losses=[2.5106738805770874, 2.3420581817626953,
                           2.28715443611145])
GOLDEN_FEDAVG = dict(clock=0.76929696, comm=4982400.0,
                     param_sum=246.36886466104056,
                     losses=[2.482684850692749, 2.34460312128067])


def _golden_engine(mode, rounds, comm=None):
    from repro.core.engine import EngineConfig, S2FLEngine
    from repro.data.partition import federate
    from repro.data.synthetic import make_image_dataset
    from repro.models import SplitModel

    ds = make_image_dataset(240, seed=0)
    fed = federate(ds, 6, alpha=0.3, seed=0)
    model = SplitModel(get_config("resnet8"))
    ecfg = EngineConfig(mode=mode, rounds=rounds, clients_per_round=4,
                        batch_size=16, group_size=2, seed=0,
                        comm=comm or CommConfig())
    eng = S2FLEngine(model, fed, ecfg)
    eng.run(rounds=rounds)
    return eng


def _param_sum(eng):
    return float(np.sum([np.asarray(l, np.float64).sum()
                         for l in jax.tree.leaves(eng.params)]))


@pytest.mark.slow
def test_golden_fp32_no_feedback_bit_exact():
    """codec=fp32, error_feedback=False must stay EXACTLY the pre-PR
    engine: same clock, same wire bytes, same trained parameters (the
    dispatch passthrough skips the model-leg walk entirely, so nothing
    new touches the fp32 path)."""
    eng = _golden_engine("s2fl", 3)
    assert eng.clock == GOLDEN_S2FL["clock"]
    assert eng.comm == GOLDEN_S2FL["comm"]
    assert _param_sum(eng) == GOLDEN_S2FL["param_sum"]
    assert [h["loss"] for h in eng.history] == GOLDEN_S2FL["losses"]
    assert eng.history[-1]["comm_dispatch"] == 0.0   # nothing metered


def test_golden_fedavg_fp32_bit_exact():
    eng = _golden_engine("fedavg", 2)
    assert eng.clock == GOLDEN_FEDAVG["clock"]
    assert eng.comm == GOLDEN_FEDAVG["comm"]
    assert _param_sum(eng) == GOLDEN_FEDAVG["param_sum"]
    assert [h["loss"] for h in eng.history] == GOLDEN_FEDAVG["losses"]


# ---------------------------------------------------------------------------
# dispatch-leg compression through the engine
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_engine_dispatch_codec_meters_and_cuts_comm():
    """An int8 dispatch codec compresses the 2|Wc| legs: the model-leg
    bytes are metered exactly, total comm shrinks vs fp32 at matched
    rounds, and training still decreases the loss."""
    base = _golden_engine("s2fl", 3)
    comp = _golden_engine("s2fl", 3,
                          comm=CommConfig(dispatch_codec="int8"))
    assert comp.history[-1]["comm_dispatch"] > 0.0
    assert comp.comm < base.comm                 # 2|Wc| really shrank
    assert comp.clock < base.clock               # and the clock follows
    losses = [h["loss"] for h in comp.history]
    assert np.isfinite(losses).all() and losses[-1] < losses[0]


@pytest.mark.slow
def test_engine_fedavg_qsgd_baseline():
    """Compressed-FedAvg: broadcast + QSGD-style int8 update upload cut
    the round bytes well below the fp32 baseline while the loss still
    tracks it closely."""
    base = _golden_engine("fedavg", 2)
    qsgd = _golden_engine("fedavg", 2,
                          comm=CommConfig(dispatch_codec="int8"))
    assert qsgd.comm < base.comm / 3.0           # ~4x fewer model bytes
    assert np.isfinite([h["loss"] for h in qsgd.history]).all()
    assert abs(qsgd.history[-1]["loss"] - base.history[-1]["loss"]) < 0.1


@pytest.mark.slow
def test_engine_uplink_topk_with_feedback_trains():
    """Top-k features + error feedback: large byte cut, loss still
    decreasing, residual state actually populated."""
    eng = _golden_engine("s2fl", 3,
                         comm=CommConfig(codec="topk", topk_frac=0.05,
                                         error_feedback=True))
    base = GOLDEN_S2FL["comm"]
    assert eng.comm < base / 2.0
    losses = [h["loss"] for h in eng.history]
    assert np.isfinite(losses).all() and losses[-1] < losses[0]
    assert eng.channel.residual_norm() > 0.0
