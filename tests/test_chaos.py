"""Chaos suite: fault-injected training with churn, exactly-once
commits, and full-state checkpoint/resume.

The driver's failure-semantics contract (core/README.md):

  * exactly-once ledger — every dispatched (round, key) work item ends
    in EXACTLY one of {committed, abandoned}; after ``flush()``,
    ``n_committed + n_abandoned == n_dispatched`` and the per-round
    records partition the dispatch set with no overlap;
  * the clock stays monotone and every link/queue drains fully under
    ANY seeded (fault plan × resource regime × mode) draw;
  * a member killed mid-flight loses exactly the contributions that had
    not committed by the kill instant — an abandoned FluidLink flow
    keeps its drained bytes, meters the remainder, and frees capacity;
  * error-feedback residuals of a dead device are quarantined, then
    restored (live-wins merge) or discarded (L2 mass metered) when it
    rejoins;
  * ``export_state``/``restore_state`` round-trip the ENTIRE timeline
    through JSON bit-exactly: a driver restored at any round replays
    the remaining rounds identical to the uninterrupted run, and the
    engine-level ``save_run_state``/``restore_run_state`` extends that
    to a full training run on the fp32 sync path.

Seeded loops (always run) provide the 20+-draw acceptance floor;
hypothesis (via tests/hypothesis_compat.py) widens the same invariants
in CI.
"""
import json
import math

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.comm import CommChannel, FluidLink
from repro.core.driver import AnalyticCost, RoundDriver, _ServerQueue
from repro.core.faults import FaultEvent, FaultPlan
from repro.core.scheduler import SlidingSplitScheduler
from repro.core.simulation import make_device_grid
from repro.core.split import SplitPlan

PLAN = SplitPlan(n_units=8, split_points=(1, 2, 4))

MODES = [("sync", False), ("sync", True),
         ("semi_async", False), ("semi_async", True)]


def _rand_costs(rng):
    out = {}
    for s in PLAN.split_points:
        out[s] = dict(wc_size=float(rng.uniform(1e4, 2e6)),
                      feat_size=float(rng.uniform(1e2, 2e4)),
                      fc=float(rng.uniform(1e7, 3e9)),
                      fs=float(rng.uniform(1e7, 3e9)))
    return out


def _resource_kw(rng):
    return dict(
        uplink_capacity=float(rng.choice([0.0, rng.uniform(1e5, 1e7)])),
        downlink_capacity=float(rng.choice([0.0, rng.uniform(1e5, 1e7)])),
        server_concurrency=int(rng.integers(0, 4)),
        gate_redispatch=bool(rng.integers(0, 2)),
        latency=float(rng.choice([0.0, rng.uniform(0.0, 0.3)])),
        latency_dist=str(rng.choice(["constant", "uniform",
                                     "lognormal", "exp"])))


def _chaos_drive(costs, fault_plan, *, n_devices, rounds, per_round,
                 quorum, cap, seed, mode="semi_async", pipeline=True,
                 latency=0.0, uplink_capacity=0.0, downlink_capacity=0.0,
                 server_concurrency=0, gate_redispatch=False,
                 latency_dist="constant",
                 scheduler=SlidingSplitScheduler):
    devices = make_device_grid(n_devices, seed=seed)
    ch = CommChannel(codec="fp32", latency=latency,
                     uplink_capacity=uplink_capacity,
                     downlink_capacity=downlink_capacity,
                     latency_dist=latency_dist)
    drv = RoundDriver(scheduler(PLAN), AnalyticCost(ch, costs, p=32),
                      devices, mode=mode, staleness_cap=cap,
                      quorum=quorum, pipeline=pipeline,
                      server_concurrency=server_concurrency,
                      gate_redispatch=gate_redispatch,
                      fault_plan=fault_plan)
    rng = np.random.default_rng(seed)
    recs = []
    for r in range(rounds):
        part = rng.choice(devices, size=per_round, replace=False)
        recs.append(drv.run_round(part))
    flushed, _ = drv.flush()
    return drv, recs, flushed


def _assert_exactly_once(drv, recs, flushed):
    """The ledger invariant: commits + abandons partition dispatches."""
    committed = [k for r in recs for k in r.committed] + list(flushed)
    abandoned = [k for r in recs for k in r.abandoned]
    dispatched = [c for r in recs for c in r.splits]
    assert sorted(committed + abandoned, key=str) \
        == sorted(dispatched, key=str)
    assert drv.n_dispatched == len(dispatched)
    assert drv.n_committed == len(committed)
    assert drv.n_abandoned == len(abandoned)
    assert drv.n_committed + drv.n_abandoned == drv.n_dispatched
    # nothing lingers: heaps empty, every flight torn down or drained
    assert not drv._pending and not drv._downloads
    assert not drv._flights


def _assert_links_drained(drv):
    """Byte conservation with kills: every flow drains fully by its own
    solved finish (abandoned flows land truncated at their kill instant)
    and metered abandoned bytes are never negative. The horizon is the
    link's own — a gated flow whose commit event was abandoned may
    finish after the flushed clock (the upload completed; only the
    commit that depended on it was torn down)."""
    for link in (drv._uplink, drv._downlink):
        if link is None or not len(link):
            continue
        assert link.abandoned_bytes >= 0.0
        fins = [f for f in link.solve() if math.isfinite(f)]
        horizon = max([drv.clock] + fins)
        rem = link.remaining_at(horizon)
        assert sum(rem) == pytest.approx(
            0.0, abs=1e-6 * max(link.submitted_bytes, 1.0))


# ---------------------------------------------------------------------------
# the acceptance floor: 24 seeded (fault plan × resource regime) draws
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", range(24))
def test_chaos_exactly_once_under_seeded_churn(seed):
    """For every seeded draw of (random fault plan, random resource
    regime, mode, pipelining): no dropped or double-counted update,
    monotone clock, bounded staleness, fully drained links."""
    rng = np.random.default_rng(seed)
    costs = _rand_costs(rng)
    n_devices = int(rng.integers(3, 9))
    rounds = int(rng.integers(3, 8))
    per_round = int(rng.integers(2, n_devices + 1))
    quorum = float(rng.uniform(0.2, 1.0))
    cap = int(rng.integers(0, 3))
    mode, pipeline = MODES[seed % len(MODES)]
    plan = FaultPlan.random(
        list(range(n_devices)), rounds, seed=seed,
        kill_prob=0.35, rejoin_prob=0.5, mid_flight_frac=0.5,
        server_policy=("cancel", "orphan")[seed % 2],
        residual_policy=("restore", "discard")[(seed // 2) % 2])
    drv, recs, flushed = _chaos_drive(
        costs, plan, n_devices=n_devices, rounds=rounds,
        per_round=per_round, quorum=quorum, cap=cap, seed=seed,
        mode=mode, pipeline=pipeline, **_resource_kw(rng))

    _assert_exactly_once(drv, recs, flushed)
    _assert_links_drained(drv)
    clocks = [0.0] + [r.clock for r in recs] + [drv.clock]
    assert all(b >= a for a, b in zip(clocks, clocks[1:]))
    assert all(r.round_time >= 0.0 for r in recs)
    for r in recs:
        assert all(v <= cap for v in r.staleness.values()), r
    # NOTE: one round's record may show the same bare key both committed
    # and abandoned — those are different DISPATCHES (a stale key from an
    # earlier round committing while the fresh incarnation is torn down).
    # Exactly-once identity is (dispatch round, key): the multiset
    # equality in _assert_exactly_once is the real invariant.


def test_chaos_without_faults_degenerates_to_baseline():
    """An empty fault plan must be indistinguishable from no plan."""
    rng = np.random.default_rng(7)
    costs = _rand_costs(rng)
    kw = dict(n_devices=5, rounds=4, per_round=4, quorum=0.5, cap=1,
              seed=7, mode="semi_async", pipeline=True)
    base, base_recs, base_fl = _chaos_drive(costs, None, **kw)
    empt, empt_recs, empt_fl = _chaos_drive(costs, FaultPlan([]), **kw)
    assert base.clock == empt.clock
    assert base.n_abandoned == empt.n_abandoned == 0
    assert [r.committed for r in base_recs] \
        == [r.committed for r in empt_recs]
    assert list(base_fl) == list(empt_fl)


def test_pre_dispatch_kill_excludes_device_until_rejoin():
    """A device killed before dispatch never enters the cohort; after
    its scheduled rejoin it is dispatched (and committed) again."""
    rng = np.random.default_rng(3)
    costs = _rand_costs(rng)
    plan = FaultPlan([FaultEvent(round=1, cid=0, kind="kill"),
                      FaultEvent(round=3, cid=0, kind="rejoin")])
    drv, recs, flushed = _chaos_drive(
        costs, plan, n_devices=3, rounds=5, per_round=3, quorum=1.0,
        cap=0, seed=3, mode="sync", pipeline=False)
    assert recs[1].killed == (0,)
    assert 0 not in recs[1].splits and 0 not in recs[2].splits
    assert recs[3].rejoined == (0,)
    assert 0 in recs[3].splits
    _assert_exactly_once(drv, recs, flushed)


def test_mid_flight_kill_abandons_only_undelivered_work():
    """at=0.0 kills at dispatch (everything of the victim's round in
    flight is lost); at=1.0 kills at the round horizon (every commit
    already landed, nothing abandoned)."""
    rng = np.random.default_rng(11)
    costs = _rand_costs(rng)
    for at, expect_abandon in ((0.0, True), (1.0, False)):
        plan = FaultPlan([FaultEvent(round=1, cid=0, kind="kill", at=at)])
        drv, recs, flushed = _chaos_drive(
            costs, plan, n_devices=3, rounds=3, per_round=3, quorum=1.0,
            cap=0, seed=11, mode="sync", pipeline=False)
        assert recs[1].killed == (0,)
        assert 0 in recs[1].splits          # dispatched before the kill
        assert (0 in recs[1].abandoned) == expect_abandon
        assert (0 in recs[1].committed) == (not expect_abandon)
        _assert_exactly_once(drv, recs, flushed)


# ---------------------------------------------------------------------------
# hypothesis widening of the same invariants (real in CI, skipped locally)
# ---------------------------------------------------------------------------
@given(seed=st.integers(0, 2**31 - 1),
       n_devices=st.integers(2, 9),
       rounds=st.integers(1, 7),
       quorum=st.floats(0.1, 1.0),
       cap=st.integers(0, 3),
       kill_prob=st.floats(0.0, 0.6))
@settings(max_examples=40, deadline=None)
def test_chaos_exactly_once_property(seed, n_devices, rounds, quorum,
                                     cap, kill_prob):
    rng = np.random.default_rng(seed)
    costs = _rand_costs(rng)
    per_round = int(rng.integers(1, n_devices + 1))
    mode, pipeline = MODES[seed % len(MODES)]
    plan = FaultPlan.random(
        list(range(n_devices)), rounds, seed=seed, kill_prob=kill_prob,
        rejoin_prob=float(rng.uniform(0.0, 1.0)),
        server_policy=str(rng.choice(["cancel", "orphan"])),
        residual_policy=str(rng.choice(["restore", "discard"])))
    drv, recs, flushed = _chaos_drive(
        costs, plan, n_devices=n_devices, rounds=rounds,
        per_round=per_round, quorum=quorum, cap=cap, seed=seed,
        mode=mode, pipeline=pipeline, **_resource_kw(rng))
    _assert_exactly_once(drv, recs, flushed)
    _assert_links_drained(drv)
    clocks = [0.0] + [r.clock for r in recs] + [drv.clock]
    assert all(b >= a for a, b in zip(clocks, clocks[1:]))
    for r in recs:
        assert all(v <= cap for v in r.staleness.values()), r


# ---------------------------------------------------------------------------
# driver checkpoint/resume: bit-equality through a JSON round-trip
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", range(8))
def test_driver_state_roundtrip_bit_exact(seed):
    """Snapshot the driver mid-run (through an actual JSON encode →
    decode, as the .npz extra side-channel does), restore into a fresh
    identically-configured driver, and replay the remaining rounds on
    the same participant schedule: every per-round record and the
    flushed clock must be bit-identical to the uninterrupted run."""
    rng = np.random.default_rng(1000 + seed)
    costs = _rand_costs(rng)
    n_devices, rounds = 6, 6
    k = int(rng.integers(1, rounds))
    parts = [sorted(rng.choice(n_devices, size=4,
                               replace=False).tolist())
             for _ in range(rounds)]
    res = _resource_kw(rng)
    mode, pipeline = MODES[seed % len(MODES)]
    plan = FaultPlan.random(list(range(n_devices)), rounds,
                            seed=seed, kill_prob=0.25)

    def mk():
        devices = make_device_grid(n_devices, seed=seed)
        ch = CommChannel(codec="fp32", latency=res["latency"],
                         uplink_capacity=res["uplink_capacity"],
                         downlink_capacity=res["downlink_capacity"],
                         latency_dist=res["latency_dist"])
        drv = RoundDriver(
            SlidingSplitScheduler(PLAN), AnalyticCost(ch, costs, p=32),
            devices, mode=mode, staleness_cap=1, quorum=0.5,
            pipeline=pipeline,
            server_concurrency=res["server_concurrency"],
            gate_redispatch=res["gate_redispatch"], fault_plan=plan)
        return drv, {d.cid: d for d in devices}

    drv_a, by_id = mk()
    recs_a, snap = [], None
    for r in range(rounds):
        if r == k:
            snap = json.loads(json.dumps(drv_a.export_state()))
        recs_a.append(drv_a.run_round([by_id[c] for c in parts[r]]))
    flushed_a, _ = drv_a.flush()

    drv_b, by_id_b = mk()
    drv_b.restore_state(snap)
    recs_b = [drv_b.run_round([by_id_b[c] for c in parts[r]])
              for r in range(k, rounds)]
    flushed_b, _ = drv_b.flush()

    assert drv_b.clock == drv_a.clock           # exact, not approx
    assert drv_b.comm == drv_a.comm
    assert list(flushed_b) == list(flushed_a)
    assert (drv_b.n_dispatched, drv_b.n_committed, drv_b.n_abandoned) \
        == (drv_a.n_dispatched, drv_a.n_committed, drv_a.n_abandoned)
    for ra, rb in zip(recs_a[k:], recs_b):
        assert rb.clock == ra.clock
        assert rb.round_time == ra.round_time
        assert rb.splits == ra.splits
        assert rb.times == ra.times
        assert rb.committed == ra.committed
        assert rb.abandoned == ra.abandoned
        assert rb.killed == ra.killed and rb.rejoined == ra.rejoined
        assert rb.staleness == ra.staleness


def test_driver_state_json_serializable_mid_flight():
    """export_state() must be pure-JSON (inf/nan flights included) at
    EVERY round boundary, not just quiescent ones."""
    rng = np.random.default_rng(5)
    costs = _rand_costs(rng)
    devices = make_device_grid(5, seed=5)
    ch = CommChannel(codec="fp32", uplink_capacity=1e6,
                     downlink_capacity=1e6)
    drv = RoundDriver(SlidingSplitScheduler(PLAN),
                      AnalyticCost(ch, costs, p=32), devices,
                      mode="semi_async", staleness_cap=2, quorum=0.3,
                      pipeline=True, server_concurrency=2)
    for r in range(4):
        drv.run_round(devices)
        st_dict = json.loads(json.dumps(drv.export_state()))
        assert st_dict["round"] == r + 1
    drv.flush()


# ---------------------------------------------------------------------------
# fault primitives: FluidLink.abandon, _ServerQueue.cancel, residual
# quarantine
# ---------------------------------------------------------------------------
def test_fluid_link_abandon_frees_capacity_and_conserves_bytes():
    link = FluidLink(100.0)
    a = link.submit(0.0, 1000.0, 100.0)
    b = link.submit(0.0, 1000.0, 100.0)
    # fair share 50 B/s each: 250 B drained apiece by t=5
    dropped = link.abandon(a, 5.0)
    assert dropped == pytest.approx(750.0)
    assert link.abandoned_bytes == pytest.approx(750.0)
    fins = link.solve()
    assert fins[a] == pytest.approx(5.0)    # lands at the kill instant
    # b: 250 B by t=5, then the whole link to itself -> 750/100 s more
    assert fins[b] == pytest.approx(12.5)
    assert sum(link.remaining_at(20.0)) == pytest.approx(0.0)
    # second abandon after the flow drained: no-op
    assert link.abandon(a, 6.0) == 0.0
    assert link.abandoned_bytes == pytest.approx(750.0)


def test_fluid_link_abandon_unstarted_flow_drops_whole():
    link = FluidLink(100.0)
    f = link.submit(10.0, 500.0, 50.0)
    assert link.abandon(f, 2.0) == pytest.approx(500.0)
    assert link.solve()[f] == pytest.approx(10.0)   # empty, at arrival
    assert link.abandoned_bytes == pytest.approx(500.0)


def test_fluid_link_abandon_leaves_survivor_history_unchanged():
    """Truncation must not rewrite the past: a survivor's drained bytes
    at any instant before the kill are identical with and without the
    abandon."""
    mk = lambda: [FluidLink(100.0)]
    (link,) = mk()
    (ref,) = mk()
    for lk in (link, ref):
        lk.submit(0.0, 2000.0, 80.0)
        lk.submit(1.0, 2000.0, 80.0)
    link.abandon(0, 6.0)
    for t in (0.5, 2.0, 4.0, 5.9):
        assert link.remaining_at(t)[1] == pytest.approx(
            ref.remaining_at(t)[1])
    # after the kill the survivor can only be ahead (capacity freed)
    assert link.remaining_at(10.0)[1] <= ref.remaining_at(10.0)[1] + 1e-9


def test_server_queue_cancel_waiting_running_finished():
    q = _ServerQueue(1)
    j0 = q.add(0.0, 10.0)           # runs [0, 10)
    j1 = q.add(1.0, 5.0)            # queued behind j0
    assert q.cancel(j1, 2.0)        # still waiting: leaves the queue
    assert q.solve()[j1] == pytest.approx(2.0)
    assert q.cancel(j0, 4.0)        # running: truncated at the kill
    assert q.solve()[j0] == pytest.approx(4.0)
    assert not q.cancel(j0, 20.0)   # already finished: no-op
    # a job admitted after the cancels is unaffected
    j2 = q.add(6.0, 3.0)
    assert q.solve()[j2] == pytest.approx(9.0)


def test_channel_residual_quarantine_restore_and_discard():
    import jax.numpy as jnp
    ch = CommChannel(codec="topk", error_feedback=True, topk_frac=0.5)
    x = jnp.arange(8.0) + 1.0
    ch.uplink_features(3, x)
    ch.uplink_features(4, x)
    assert any(k[1] == 3 for k in ch._residuals)
    norm_all = ch.residual_norm()
    ch.quarantine_residuals(3)
    assert not any(k[1] == 3 for k in ch._residuals)
    assert ch.residual_norm() < norm_all
    # restore: the quarantined accumulator returns live, bit-identical
    ch.release_residuals(3, restore=True)
    assert ch.residual_norm() == pytest.approx(norm_all)
    # restore is live-wins: a fresh residual from the new incarnation
    # survives a stale quarantined one under the same key
    ch.quarantine_residuals(3)
    ch.uplink_features(3, 2.0 * x)
    fresh = {k: v for k, v in ch._residuals.items() if k[1] == 3}
    ch.release_residuals(3, restore=True)
    for k, v in fresh.items():
        np.testing.assert_array_equal(np.asarray(ch._residuals[k]),
                                      np.asarray(v))
    # discard: mass is metered, not silently lost
    ch.quarantine_residuals(4)
    held_norm = ch.residual_norm()          # only cid 3 left live
    ch.release_residuals(4, restore=False)
    assert ch.ef_discarded_mass > 0.0
    assert ch.residual_norm() == pytest.approx(held_norm)
    # releasing a device with nothing quarantined is a no-op
    before = ch.ef_discarded_mass
    ch.release_residuals(99, restore=False)
    assert ch.ef_discarded_mass == before


def test_residual_state_flat_roundtrip():
    import jax.numpy as jnp
    ch = CommChannel(codec="topk", error_feedback=True, topk_frac=0.5)
    ch.uplink_features(np.int64(2), jnp.arange(6.0) + 1.0)
    ch.uplink_features(5, jnp.arange(6.0) * 3.0 + 1.0)
    ch.quarantine_residuals(5)
    flat = ch.export_residual_state()
    assert all(n[:2] in ("r:", "q:") for n in flat)
    other = CommChannel(codec="topk", error_feedback=True, topk_frac=0.5)
    other.restore_residual_state(flat)
    assert set(other._residuals) == set(ch._residuals)
    assert set(other._quarantine) == set(ch._quarantine)
    with pytest.raises(ValueError, match="unknown residual"):
        other.restore_residual_state({"x:[1]": jnp.zeros(2)})


# ---------------------------------------------------------------------------
# fault-plan object: determinism, validation, serialization
# ---------------------------------------------------------------------------
def test_fault_plan_random_is_deterministic_and_sane():
    cids = list(range(6))
    a = FaultPlan.random(cids, 10, seed=42, kill_prob=0.4)
    b = FaultPlan.random(cids, 10, seed=42, kill_prob=0.4)
    assert a.events == b.events
    c = FaultPlan.random(cids, 10, seed=43, kill_prob=0.4)
    assert a.events != c.events             # seed actually matters
    # a device is never killed twice without a rejoin in between
    dead = set()
    for e in a.events:
        if e.kind == "kill":
            assert e.cid not in dead
            dead.add(e.cid)
        else:
            assert e.cid in dead
            dead.discard(e.cid)


def test_fault_plan_validation_and_file_roundtrip(tmp_path):
    with pytest.raises(ValueError):
        FaultEvent(round=0, cid=1, kind="explode")
    with pytest.raises(ValueError):
        FaultEvent(round=0, cid=1, kind="kill", at=1.5)
    with pytest.raises(ValueError):
        FaultPlan([], server_policy="shrug")
    plan = FaultPlan([FaultEvent(round=2, cid=1, kind="kill", at=0.25),
                      FaultEvent(round=4, cid=1, kind="rejoin")],
                     server_policy="orphan", residual_policy="discard")
    p = tmp_path / "plan.json"
    plan.to_file(str(p))
    back = FaultPlan.from_file(str(p))
    assert back.events == plan.events
    assert back.server_policy == "orphan"
    assert back.residual_policy == "discard"
    assert len(back) == 2
    # rejoins order before kills within a round
    mixed = FaultPlan([FaultEvent(round=1, cid=0, kind="kill"),
                       FaultEvent(round=1, cid=1, kind="rejoin")])
    kinds = [e.kind for e in mixed.for_round(1)]
    assert kinds == ["rejoin", "kill"]


# ---------------------------------------------------------------------------
# engine level (training-heavy: quick loop skips these via -m "not slow")
# ---------------------------------------------------------------------------
def _tiny_engine(mode="s2fl", rounds=4, *, fault_plan=None, seed=0,
                 exec_mode="sync", pipeline=False):
    from repro.configs import get_config
    from repro.configs.base import DriverConfig
    from repro.core.engine import EngineConfig, S2FLEngine
    from repro.data.partition import federate
    from repro.data.synthetic import make_image_dataset
    from repro.models import SplitModel
    ds = make_image_dataset(160, seed=0)
    fed = federate(ds, 5, alpha=0.3, seed=0)
    model = SplitModel(get_config("resnet8"))
    ecfg = EngineConfig(mode=mode, rounds=rounds, clients_per_round=3,
                        batch_size=8, group_size=2, local_steps=1,
                        seed=seed,
                        driver=DriverConfig(exec_mode=exec_mode,
                                            pipeline=pipeline))
    return S2FLEngine(model, fed, ecfg, fault_plan=fault_plan)


@pytest.mark.slow
def test_engine_crash_and_resume_is_bit_exact(tmp_path):
    """The acceptance criterion: on the fp32 sync path, run(2) →
    save_run_state → fresh engine → restore_run_state → run(2) must
    reproduce run(4)'s parameters and history bit-for-bit."""
    import jax

    from repro.checkpoint import restore_run_state, save_run_state
    eng_a = _tiny_engine(rounds=4)
    eng_a.run(rounds=4)

    eng_b = _tiny_engine(rounds=4)
    eng_b.run(rounds=2)
    path = str(tmp_path / "mid.npz")
    save_run_state(path, eng_b)

    eng_c = _tiny_engine(rounds=4)
    restore_run_state(path, eng_c)
    assert len(eng_c.history) == 2
    eng_c.run(rounds=2)

    for a, c in zip(jax.tree.leaves(eng_a.params),
                    jax.tree.leaves(eng_c.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
    assert eng_c.clock == eng_a.clock
    assert len(eng_c.history) == len(eng_a.history) == 4
    for ha, hc in zip(eng_a.history, eng_c.history):
        assert ha == hc


@pytest.mark.slow
def test_restore_rejects_wrong_mode_and_format(tmp_path):
    from repro.checkpoint import (restore_run_state, save_checkpoint,
                                  save_run_state)
    eng = _tiny_engine(rounds=1)
    eng.run(rounds=1)
    path = str(tmp_path / "st.npz")
    save_run_state(path, eng)
    other = _tiny_engine(mode="fedavg", rounds=1)
    with pytest.raises(ValueError, match="mode"):
        restore_run_state(path, other)
    plain = str(tmp_path / "plain.npz")
    save_checkpoint(plain, {"w": np.zeros(3)})
    with pytest.raises(ValueError, match="run-state"):
        restore_run_state(plain, eng)


@pytest.mark.slow
def test_engine_chaos_run_balances_ledger():
    """A real training run under churn: the engine's held-work table
    empties, the ledger balances, and the timeline stays finite."""
    plan = FaultPlan.random(list(range(5)), 5, seed=9, kill_prob=0.35,
                            rejoin_prob=0.6)
    eng = _tiny_engine(rounds=5, fault_plan=plan,
                       exec_mode="semi_async", pipeline=True)
    eng.run(rounds=5)
    drv = eng.driver
    assert drv.n_dispatched > 0
    assert drv.n_committed + drv.n_abandoned == drv.n_dispatched
    assert not eng._held
    assert not drv._pending and not drv._flights
    assert math.isfinite(eng.clock)
    assert all(math.isfinite(h["loss"]) for h in eng.history
               if h.get("loss") is not None)


@pytest.mark.slow
def test_engine_fedavg_chaos_run_balances_ledger():
    plan = FaultPlan.random(list(range(5)), 4, seed=2, kill_prob=0.3)
    eng = _tiny_engine(mode="fedavg", rounds=4, fault_plan=plan,
                       exec_mode="semi_async", pipeline=True)
    eng.run(rounds=4)
    drv = eng.driver
    assert drv.n_committed + drv.n_abandoned == drv.n_dispatched
    assert not eng._held
