"""Integration tests: Algorithm-2 engine end-to-end (all three modes +
ablations), and equivalence of the fused SPMD round step with the host
engine at E=1."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, make_reduced
from repro.core.engine import EngineConfig, S2FLEngine
from repro.core.round_step import make_s2fl_loss, make_s2fl_train_step
from repro.data.partition import federate
from repro.data.synthetic import make_image_dataset, make_lm_dataset
from repro.models import SplitModel

# training-heavy module: the quick loop skips it (-m "not slow"; see pytest.ini)
pytestmark = pytest.mark.slow


KEY = jax.random.PRNGKey(0)


def _cnn_setup(n=400, clients=6):
    ds = make_image_dataset(n, seed=0)
    fed = federate(ds, clients, alpha=0.3, seed=0)
    model = SplitModel(get_config("resnet8"))
    return model, fed, make_image_dataset(120, seed=9)


@pytest.mark.parametrize("mode", ["s2fl", "sfl", "fedavg"])
def test_engine_modes_run_and_learn(mode):
    model, fed, test = _cnn_setup()
    ecfg = EngineConfig(mode=mode, rounds=3, clients_per_round=4,
                        batch_size=16, group_size=2, local_steps=1)
    eng = S2FLEngine(model, fed, ecfg)
    before = eng.evaluate(test)["loss"]
    eng.run(rounds=3)
    after = eng.evaluate(test)["loss"]
    assert np.isfinite(after)
    assert after < before + 0.15          # not diverging
    assert eng.clock > 0 and eng.comm > 0
    assert len(eng.history) == 3


def test_engine_ablation_flags():
    model, fed, _ = _cnn_setup(n=200, clients=4)
    # S2FL+B (no sliding) and S2FL+M (no balance) both run
    for kw in ({"use_sliding": False}, {"use_balance": False}):
        ecfg = EngineConfig(mode="s2fl", rounds=2, clients_per_round=3,
                            batch_size=8, **kw)
        eng = S2FLEngine(model, fed, ecfg)
        eng.run(rounds=2)
        assert len(eng.history) == 2


def test_run_flush_patch_is_idempotent():
    """run() folds the flush tail into history[-1] only when the flush
    actually advanced something: a sync run (nothing ever pending) and a
    repeated run()/flush must leave the final record untouched."""
    import copy

    from repro.configs.base import DriverConfig

    model, fed, _ = _cnn_setup(n=200, clients=4)
    # sync: every round commits inside itself -> flush finds nothing
    eng = S2FLEngine(model, fed, EngineConfig(
        mode="s2fl", rounds=2, clients_per_round=3, batch_size=8))
    eng.run(rounds=2)
    last = copy.deepcopy(eng.history[-1])
    assert last["pending"] == 0
    eng.run(rounds=0)                     # flush again, nothing pending
    assert eng.history[-1] == last and len(eng.history) == 2

    # semi_async pipelined: the first flush really patches, the second
    # run(rounds=0) must be a no-op on the already-honest record
    eng = S2FLEngine(model, fed, EngineConfig(
        mode="s2fl", rounds=2, clients_per_round=3, batch_size=8,
        driver=DriverConfig(exec_mode="semi_async", pipeline=True)))
    eng.run(rounds=2)
    last = copy.deepcopy(eng.history[-1])
    assert last["pending"] == 0 and last["clock"] == eng.clock
    eng.run(rounds=0)
    assert eng.history[-1] == last


def test_scheduler_beats_fixed_split_on_vgg16_clock():
    """Straggler mitigation (Table 3 regime): on VGG16, where |Wc| upload
    dominates Eq. 1, the sliding split must cut the per-round wall time vs
    SFL's fixed largest split. Pure Eq.-1 simulation (no training), exactly
    how the paper's time numbers arise.

    Note: on ResNet8 this does NOT hold — the model is tiny and early
    feature maps are big, so small client portions increase feature-upload
    time; see benchmarks/time_comm.py for the per-model discussion.
    """
    from repro.comm import CommChannel
    from repro.core.scheduler import SlidingSplitScheduler
    from repro.core.simulation import make_device_grid
    from repro.core.split import default_plan
    from repro.utils.flops import split_costs

    model = SplitModel(get_config("vgg16"))
    plan = default_plan(model.n_units, k=3)
    costs = {s: split_costs(model, s) for s in plan.split_points}
    devices = make_device_grid(9, seed=0)
    p = 32
    ch = CommChannel()

    def t_of(dev, s):
        c = costs[s]
        t, _ = ch.analytic_round_time(dev, wc_size=c["wc_size"],
                                      n_values=p * c["feat_size"],
                                      fc=p * c["fc"], fs=p * c["fs"],
                                      t=0.0)
        return t

    # SFL: everyone trains the largest portion
    sfl_wall = max(t_of(d, plan.largest()) for d in devices)

    # S²FL: warm-up then median matching
    sched = SlidingSplitScheduler(plan)
    ids = [d.cid for d in devices]
    for _ in range(plan.k):
        sel = sched.select(ids)
        for d in devices:
            sched.observe(d.cid, sel[d.cid], t_of(d, sel[d.cid]))
        sched.end_round()
    sel = sched.select(ids)
    s2_wall = max(t_of(d, sel[d.cid]) for d in devices)
    assert s2_wall < sfl_wall
    # and the spread of times tightens (the paper's equalization goal)
    sfl_times = [t_of(d, plan.largest()) for d in devices]
    s2_times = [t_of(d, sel[d.cid]) for d in devices]
    assert (max(s2_times) - min(s2_times)) < (max(sfl_times)
                                              - min(sfl_times))


def test_engine_lm_arch():
    """The engine drives an LM arch (split federated LM training)."""
    cfg = make_reduced(get_config("internlm2-1.8b"))
    ds = make_lm_dataset(240, seq_len=32, vocab=min(cfg.vocab_size, 256),
                         seed=0)
    fed = federate(ds, 4, alpha=0.5, seed=0)
    model = SplitModel(cfg)
    ecfg = EngineConfig(mode="s2fl", rounds=2, clients_per_round=3,
                        batch_size=8, group_size=2)
    eng = S2FLEngine(model, fed, ecfg)
    eng.run(rounds=2)
    assert np.isfinite(eng.history[-1]["loss"])


def test_fused_round_step_matches_engine_e1():
    """The pod-scale fused step (round_step.py) must reproduce the host
    engine's E=1 round exactly: same grouping, same SGD update, same
    aggregated params."""
    cfg = make_reduced(get_config("internlm2-1.8b"))
    model = SplitModel(cfg)
    params = model.init(KEY)
    split, n_groups, lr = 1, 2, 0.05
    B, S = 8, 16
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    perm = jnp.asarray(np.random.default_rng(0).permutation(B), jnp.int32)
    batch = {"tokens": tokens, "labels": labels, "perm": perm}

    step = make_s2fl_train_step(cfg, split, n_groups, lr)
    new_params, loss = jax.jit(step)(params, batch)

    # manual reference: permute, split into groups, mean of group losses
    def ref_loss(p):
        feats = model.client_forward(p, {"tokens": tokens}, split)
        h = feats["h"][perm]
        t_p, l_p = tokens[perm], labels[perm]
        gb = B // n_groups
        losses = []
        for g in range(n_groups):
            sl = slice(g * gb, (g + 1) * gb)
            l, _ = model.server_loss(
                p, {"h": h[sl], "aux": jnp.zeros((), jnp.float32)},
                {"tokens": t_p[sl], "labels": l_p[sl]}, split)
            losses.append(l)
        return jnp.mean(jnp.stack(losses)) + feats["aux"]

    ref_l, ref_g = jax.value_and_grad(ref_loss)(params)
    np.testing.assert_allclose(float(loss), float(ref_l), rtol=1e-5)
    ref_new = jax.tree.map(lambda w, g: w - lr * g.astype(w.dtype),
                           params, ref_g)
    for a, b in zip(jax.tree.leaves(new_params), jax.tree.leaves(ref_new)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-5)


def test_fused_loss_balance_permutation_changes_groups():
    """Different perms -> different group compositions -> different loss
    (the mechanism actually routes features)."""
    cfg = make_reduced(get_config("internlm2-1.8b"))
    loss_fn = make_s2fl_loss(cfg, split=1, n_groups=2)
    model = SplitModel(cfg)
    params = model.init(KEY)
    B, S = 8, 16
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    base = {"tokens": tokens, "labels": labels,
            "perm": jnp.arange(B, dtype=jnp.int32)}
    l1 = loss_fn(params, base)
    # loss is mean over groups of per-group CE; permuting only relabels
    # which rows are in which group, but CE is per-row -> overall mean
    # equals ungrouped mean. Verify invariance (sanity of the fusion).
    perm = jnp.asarray(np.random.default_rng(1).permutation(B), jnp.int32)
    l2 = loss_fn(params, dict(base, perm=perm))
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
