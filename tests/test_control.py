"""Resource-aware control plane (core/control.py + observe/history.py).

Covers the tentpole pieces end to end:

  * RoundTimeTracker: EMA/quantile band learning + state round-trip;
  * ResourceView: live queue/link/gate reads, per-(round, clock)
    caching, residual mass;
  * resource_aware_forecast: EXACT against the realized pipelined
    round time on an uncontended static fabric, gate-wait additivity
    (never underestimates a device with a draining download), bounded
    ratio vs realized time under random (slots, uplink, downlink,
    gate) regimes, residual re-split penalty;
  * JointKnobScheduler: frac pricing + data-preserving tie rule;
  * AggregationController: successive probing, argmin lock, and the
    driver's staleness-safety rule when the cap moves under pending
    stragglers.
"""
import numpy as np
import pytest

from repro.comm import CommChannel
from repro.core.control import (AggregationController, default_knob_grid,
                                resource_aware_forecast)
from repro.core.driver import AnalyticCost, RoundDriver
from repro.core.scheduler import JointKnobScheduler, MinTimeScheduler
from repro.core.simulation import make_device_grid
from repro.core.split import SplitPlan
from repro.observe.history import RoundTimeTracker

PLAN = SplitPlan(n_units=8, split_points=(1, 2, 4))


def _rand_costs(rng):
    out = {}
    for s in PLAN.split_points:
        out[s] = dict(wc_size=float(rng.uniform(1e4, 2e6)),
                      feat_size=float(rng.uniform(1e2, 2e4)),
                      fc=float(rng.uniform(1e7, 3e9)),
                      fs=float(rng.uniform(1e7, 3e9)))
    return out


def _aware_driver(costs, *, n_devices=6, seed=0, latency=0.0,
                  uplink_capacity=0.0, downlink_capacity=0.0,
                  server_concurrency=0, gate_redispatch=False,
                  quorum=0.5, cap=1, scheduler=None,
                  knob_controller=None):
    devices = make_device_grid(n_devices, seed=seed)
    ch = CommChannel(codec="fp32", latency=latency,
                     uplink_capacity=uplink_capacity,
                     downlink_capacity=downlink_capacity)
    drv = RoundDriver(scheduler or MinTimeScheduler(PLAN),
                      AnalyticCost(ch, costs, p=32), devices,
                      mode="semi_async", pipeline=True, quorum=quorum,
                      staleness_cap=cap, resource_aware=True,
                      server_concurrency=server_concurrency,
                      gate_redispatch=gate_redispatch,
                      knob_controller=knob_controller)
    return drv, devices


# ---------------------------------------------------------------------------
# RoundTimeTracker
# ---------------------------------------------------------------------------
def test_history_band_orders_and_brackets_ema():
    tr = RoundTimeTracker(window=16, ema=0.3)
    rng = np.random.default_rng(0)
    for t in rng.uniform(1.0, 3.0, size=12):
        tr.observe("c", float(t))
    lo, mid, hi = tr.band("c")
    assert lo <= mid <= hi
    assert mid == pytest.approx(tr.ema_of("c"))
    assert tr.quantile("c", 0.0) == pytest.approx(min(tr._recent["c"]))
    assert tr.quantile("c", 1.0) == pytest.approx(max(tr._recent["c"]))
    assert tr.band("never-seen") is None


def test_history_state_round_trip_bit_exact():
    tr = RoundTimeTracker(window=8)
    rng = np.random.default_rng(1)
    for cid in (0, 1, "x"):
        for t in rng.uniform(0.1, 9.0, size=13):
            tr.observe(cid, float(t))
    clone = RoundTimeTracker(window=8)
    clone.restore_state(tr.export_state())
    for cid in (0, 1, "x"):
        assert clone.ema_of(cid) == tr.ema_of(cid)
        assert clone.band(cid) == tr.band(cid)
        assert clone.n(cid) == tr.n(cid)


# ---------------------------------------------------------------------------
# ResourceView
# ---------------------------------------------------------------------------
def test_view_reads_live_queue_and_link_state():
    rng = np.random.default_rng(2)
    costs = _rand_costs(rng)
    drv, devices = _aware_driver(costs, uplink_capacity=2e5,
                                 downlink_capacity=2e5,
                                 server_concurrency=1,
                                 gate_redispatch=True)
    for _ in range(4):
        part = rng.choice(devices, size=4, replace=False)
        drv.run_round(part)
    v = drv.view
    assert v.clock == drv.clock
    assert v.server_slots == 1
    assert v.gated
    assert v.server_depth() == drv._srvq.depth_at(drv.clock)
    n_up, bl_up = v.uplink_backlog()
    assert n_up >= 0 and bl_up >= 0.0
    if drv._uplink is not None and len(drv._uplink):
        assert (n_up, bl_up) == drv._uplink.backlog_at(drv.clock)
    # a device with a live download is busy until its drain end
    for cid, end in drv._dev_busy.items():
        assert v.busy_until(cid) == end
    drv.flush()


def test_view_caches_per_round_and_clock():
    rng = np.random.default_rng(3)
    drv, devices = _aware_driver(_rand_costs(rng), server_concurrency=2)
    drv.run_round(devices[:3])
    calls = {"n": 0}
    orig = drv._srvq.depth_at

    def counting(t):
        calls["n"] += 1
        return orig(t)

    drv._srvq.depth_at = counting
    assert drv.view.server_depth() == drv.view.server_depth()
    assert calls["n"] == 1          # second read served from the cache
    drv.flush()


def test_view_residual_mass_prices_resplit():
    """A device holding error-feedback residuals sees any CHANGED split
    priced above keeping its current one (the residual elements would
    be discarded by the shape change and must cross the wire again)."""
    import jax.numpy as jnp
    rng = np.random.default_rng(4)
    costs = _rand_costs(rng)
    devices = make_device_grid(3, seed=0)
    ch = CommChannel(codec="topk", error_feedback=True)
    drv = RoundDriver(MinTimeScheduler(PLAN),
                      AnalyticCost(ch, costs, p=32), devices,
                      mode="semi_async", pipeline=True,
                      resource_aware=True)
    cid = devices[0].cid
    drv._last_split[cid] = 2
    assert drv.view.residual_elements(cid) == 0.0
    ch._residuals[("uplink", cid, 0)] = jnp.ones((64,))
    assert drv.view.residual_elements(cid) == 64.0
    keep = drv._forecast(cid, 2, 1.0)
    move = drv._forecast(cid, 4, 1.0)
    ch._residuals.clear()
    free = drv._forecast(cid, 4, 1.0)
    assert move > free              # the penalty is the only difference
    assert keep == drv._forecast(cid, 2, 1.0)   # keeping split: no charge


# ---------------------------------------------------------------------------
# the forecast vs the simulator's physics
# ---------------------------------------------------------------------------
def test_forecast_exact_on_uncontended_static_fabric():
    """With no contention, no queue bound, no gate and a static link,
    the resource-aware forecast IS the pipelined phase sum — it must
    reproduce the realized per-device round time exactly."""
    rng = np.random.default_rng(5)
    costs = _rand_costs(rng)
    drv, devices = _aware_driver(costs, latency=0.01, quorum=1.0)
    realized = {}
    sched_observe = drv.scheduler.observe

    def spy(cid, split, t):
        realized[cid, split] = t
        sched_observe(cid, split, t)

    drv.scheduler.observe = spy
    for r in range(4):
        part = rng.choice(devices, size=3, replace=False)
        pre = {}
        for d in part:
            s = (drv.scheduler.warmup_split() if drv.scheduler.warming_up
                 else None)
            for cand in PLAN.split_points:
                pre[d.cid, cand] = drv._forecast(d.cid, cand, 1.0)
        rec = drv.run_round(part)
        for cid, s in rec.splits.items():
            assert pre[cid, s] == pytest.approx(realized[cid, s],
                                                rel=1e-9)
    drv.flush()


def test_forecast_never_underestimates_draining_device():
    """Gate-wait additivity: on a static fabric a device whose own
    download drains until T sees every candidate priced exactly
    (T - clock) above its idle price — the aware forecast can never
    underestimate a busy device."""
    rng = np.random.default_rng(6)
    costs = _rand_costs(rng)
    drv, devices = _aware_driver(costs, gate_redispatch=True)
    drv.run_round(devices[:3])
    cid = devices[0].cid
    drv._dev_busy.pop(cid, None)       # establish a truly idle baseline
    idle = {s: drv._forecast(cid, s, 1.0) for s in PLAN.split_points}
    delta = 7.5
    drv._dev_busy[cid] = drv.clock + delta
    for s in PLAN.split_points:
        busy = drv._forecast(cid, s, 1.0)
        assert busy == pytest.approx(idle[s] + delta, rel=1e-9)
        assert busy >= idle[s]
    drv.flush()


@pytest.mark.parametrize("seed", range(24))
def test_forecast_bounded_ratio_under_random_regimes(seed):
    """Under random (slots, uplink, downlink, gate) regimes the aware
    forecast stays within a bounded factor of the realized pipelined
    round time — it prices waits it cannot see exactly (future
    arrivals, fluid shares) but never departs from the physics by more
    than the regime's own variability. Seeded draws (not hypothesis)
    so the 24 regimes run identically in every image; K=6 brackets the
    worst observed seed with margin, and the uncontended case above
    pins exactness."""
    K = 6.0
    rng = np.random.default_rng(seed)
    costs = _rand_costs(rng)
    drv, devices = _aware_driver(
        costs, n_devices=int(rng.integers(3, 8)), seed=seed,
        uplink_capacity=float(rng.choice([0.0, rng.uniform(1e5, 1e7)])),
        downlink_capacity=float(rng.choice([0.0, rng.uniform(1e5, 1e7)])),
        server_concurrency=int(rng.integers(0, 4)),
        gate_redispatch=bool(rng.integers(0, 2)),
        latency=float(rng.choice([0.0, rng.uniform(0.0, 0.1)])))
    realized = {}
    sched_observe = drv.scheduler.observe

    def spy(cid, split, t):
        realized[cid, split] = t
        sched_observe(cid, split, t)

    drv.scheduler.observe = spy
    per_round = max(2, len(devices) // 2)
    for r in range(5):
        part = rng.choice(devices, size=per_round, replace=False)
        pre = {(d.cid, s): drv._forecast(d.cid, s, 1.0)
               for d in part for s in PLAN.split_points}
        rec = drv.run_round(part)
        for cid, s in rec.splits.items():
            f, t = pre[cid, s], realized[cid, s]
            assert f > 0.0 and t > 0.0
            assert 1.0 / K <= f / t <= K, (seed, r, cid, s, f, t)
    drv.flush()


# ---------------------------------------------------------------------------
# JointKnobScheduler
# ---------------------------------------------------------------------------
def _warmed_joint(fracs=(1.0, 0.75, 0.5), tol=0.1):
    sched = JointKnobScheduler(PLAN, batch_fracs=fracs,
                               frac_tolerance=tol)
    for r in range(PLAN.k):            # warm the table past warm-up
        s = sched.warmup_split()
        for c in range(3):
            sched.observe(c, s, 10.0 + c)
        sched.end_round()
    return sched


def test_joint_scheduler_validates_fracs():
    with pytest.raises(ValueError):
        JointKnobScheduler(PLAN, batch_fracs=(1.5,))
    with pytest.raises(ValueError):
        JointKnobScheduler(PLAN, batch_fracs=())
    with pytest.raises(ValueError):
        JointKnobScheduler(PLAN, frac_tolerance=-0.1)


def test_joint_scheduler_prefers_data_when_time_is_flat():
    """When the forecast is frac-independent every candidate ties, and
    the tie rule keeps the FULL batch — the knob never sacrifices
    samples for nothing."""
    sched = _warmed_joint()
    sched.forecast_frac = lambda cid, s, t, f: 10.0
    sched.select([0, 1, 2])
    assert all(f == 1.0 for f in sched.selected_fracs.values())


def test_joint_scheduler_buys_time_with_fraction_when_it_pays():
    """When time scales with the fraction (compute/payload-dominated
    device) the smallest candidate frac wins by more than the
    tolerance, so the scheduler spends samples for clock."""
    sched = _warmed_joint()
    sched.forecast_frac = lambda cid, s, t, f: 10.0 * f
    sched.select([0, 1, 2])
    assert all(f == 0.5 for f in sched.selected_fracs.values())


def test_joint_scheduler_without_hook_degenerates_to_mintime():
    sched = _warmed_joint()
    ref = MinTimeScheduler(PLAN)
    for r in range(PLAN.k):
        s = ref.warmup_split()
        for c in range(3):
            ref.observe(c, s, 10.0 + c)
        ref.end_round()
    assert sched.select([0, 1, 2]) == ref.select([0, 1, 2])
    assert all(f == 1.0 for f in sched.selected_fracs.values())


def test_joint_fracs_scale_driver_cost_model():
    """End to end: the driver wires selected_fracs into the cost
    model's frac_of, so a 0.5 frac halves the priced sample count."""
    rng = np.random.default_rng(7)
    costs = _rand_costs(rng)
    sched = JointKnobScheduler(PLAN)
    drv, devices = _aware_driver(costs, scheduler=sched)
    assert drv.cost.frac_of is not None
    sched.selected_fracs = {devices[0].cid: 0.5}
    assert drv.cost._p_eff(devices[0].cid) == 16       # p=32 halved
    assert drv.cost._p_eff(devices[1].cid) == 32


# ---------------------------------------------------------------------------
# AggregationController + driver knob safety
# ---------------------------------------------------------------------------
def test_controller_probes_in_order_then_locks_argmin():
    grid = default_knob_grid(0.5, 1)
    ctl = AggregationController(grid, probe_rounds=2)
    # feed each setting a distinct mean; the best is the third
    means = [5.0, 4.0, 1.0, 9.0][:len(grid)]
    for i, m in enumerate(means):
        assert ctl.current() == grid[i]
        for _ in range(2):
            ctl.observe(m)
    assert ctl.locked == 2
    assert ctl.current() == grid[2]
    ctl.observe(100.0)                 # post-lock feed is a no-op
    assert ctl.current() == grid[2]


def test_controller_state_round_trip():
    ctl = AggregationController(default_knob_grid(0.5, 1),
                                probe_rounds=3)
    for t in (1.0, 2.0, 3.0, 4.0):
        ctl.observe(t)
    clone = AggregationController([(0.9, 0)])
    clone.restore_state(ctl.export_state())
    assert clone.current() == ctl.current()
    assert clone._sums == ctl._sums and clone._counts == ctl._counts


def test_controller_rejects_bad_settings():
    with pytest.raises(ValueError):
        AggregationController([])
    with pytest.raises(ValueError):
        AggregationController([(0.0, 1)])
    with pytest.raises(ValueError):
        AggregationController([(0.5, -1)])


def test_driver_knob_cap_never_violates_pending_staleness():
    """A controller that probes a LOWER cap while stragglers from older
    rounds are still pending must not break the staleness invariant:
    the driver clamps the applied cap to the oldest pending age, and
    every committed window still satisfies v <= staleness_cap."""
    rng = np.random.default_rng(8)
    costs = _rand_costs(rng)
    ctl = AggregationController([(0.3, 3), (0.9, 0), (0.5, 1)],
                                probe_rounds=2)
    drv, devices = _aware_driver(costs, quorum=0.3, cap=3,
                                 knob_controller=ctl)
    recs = []
    for r in range(10):
        part = rng.choice(devices, size=4, replace=False)
        recs.append(drv.run_round(part))
        age = max((drv.round - e.round for e in drv._pending), default=0)
        assert drv.staleness_cap >= age
    flushed, _ = drv.flush()
    committed = [k for r in recs for k in r.committed] + list(flushed)
    assert sorted(committed) == sorted(c for r in recs for c in r.splits)
    assert ctl.locked is not None       # 3 settings x 2 rounds < 10


def test_driver_checkpoints_control_plane_state():
    """export_state/restore_state round-trips the history tracker, the
    last-split map and the knob controller (resumed runs keep learning
    from where they stopped)."""
    rng = np.random.default_rng(9)
    costs = _rand_costs(rng)
    mk = lambda: _aware_driver(
        costs, knob_controller=AggregationController(
            default_knob_grid(0.5, 1), probe_rounds=2))
    drv, devices = mk()
    for r in range(5):
        drv.run_round(rng.choice(devices, size=3, replace=False))
    st_ = drv.export_state()
    clone, _ = mk()
    clone.restore_state(st_)
    assert clone._last_split == drv._last_split
    assert clone._history.export_state() == drv._history.export_state()
    assert clone.knob_controller.export_state() \
        == drv.knob_controller.export_state()
    assert (clone.quorum, clone.staleness_cap) \
        == (drv.quorum, drv.staleness_cap)
    drv.flush()


def test_aware_forecast_none_for_non_analytic_cost():
    """Cost models without the analytic surface fall back to the blind
    path instead of crashing."""
    class Opaque:
        pass
    assert resource_aware_forecast(None, Opaque(), None, 2, 1.0) is None


# ---------------------------------------------------------------------------
# AggregationController loss-delta guard
# ---------------------------------------------------------------------------
def _probe_sweep(ctl, times, losses):
    """Drive one full sweep: per round, observe(time) then
    observe_loss(loss) — the engine's call order."""
    for t, lo in zip(times, losses):
        ctl.observe(t)
        ctl.observe_loss(lo)


def test_controller_rejects_fast_but_lossy_setting():
    """The loss guard: a setting that wins on round time but whose
    mean per-round loss delta regresses > loss_tol past the anchor's
    is disqualified before the argmin."""
    ctl = AggregationController([(0.9, 0), (0.5, 1), (0.3, 3)],
                                probe_rounds=2, loss_tol=0.25)
    # per-round (time, loss): s0 slow/learning, s1 mid/learning,
    # s2 fastest but loss climbs +1.0/round
    _probe_sweep(ctl,
                 times=[5.0, 5.0, 4.0, 4.0, 1.0, 1.0],
                 losses=[10.0, 9.9, 9.8, 9.7, 10.7, 11.7])
    assert ctl.locked == 1                      # argmin over survivors
    assert ctl.rejected == (2,)
    deltas = ctl.loss_delta_means()
    assert deltas[0] < 0 and deltas[1] < 0 and deltas[2] > 0.9


def test_controller_anchor_never_rejected():
    """Index 0 is the configured pair — even if every probe regresses
    loss, the anchor survives and wins when all others are rejected."""
    ctl = AggregationController([(0.9, 0), (0.5, 1), (0.3, 3)],
                                probe_rounds=2, loss_tol=0.25)
    _probe_sweep(ctl,
                 times=[9.0, 9.0, 1.0, 1.0, 1.0, 1.0],
                 losses=[10.0, 9.9, 11.9, 13.9, 15.9, 17.9])
    assert ctl.locked == 0                      # slowest, but only safe
    assert set(ctl.rejected) == {1, 2}


def test_controller_without_loss_signal_is_time_only():
    """No observe_loss calls -> the original time-argmin tuner, no
    rejections (backward-compatible default)."""
    ctl = AggregationController([(0.9, 0), (0.5, 1), (0.3, 3)],
                                probe_rounds=2)
    for t in (5.0, 5.0, 4.0, 4.0, 1.0, 1.0):
        ctl.observe(t)
    assert ctl.locked == 2
    assert ctl.rejected == ()


def test_controller_skips_non_finite_losses():
    ctl = AggregationController([(0.9, 0), (0.5, 1)], probe_rounds=1)
    ctl.observe(2.0)
    ctl.observe_loss(float("nan"))              # neither poisons nor
    ctl.observe_loss(10.0)                      # resets the base
    ctl.observe(1.0)
    ctl.observe_loss(float("inf"))
    assert ctl.locked is not None
    assert ctl.loss_delta_means()[1] is None    # inf never accrued


def test_controller_loss_state_round_trip_and_legacy_compat():
    ctl = AggregationController([(0.9, 0), (0.5, 1), (0.3, 3)],
                                probe_rounds=2, loss_tol=0.1)
    _probe_sweep(ctl, times=[5.0, 5.0, 4.0], losses=[10.0, 9.9, 9.8])
    st = ctl.export_state()
    clone = AggregationController([(0.9, 0)])
    clone.restore_state(st)
    assert clone.loss_delta_means() == ctl.loss_delta_means()
    assert clone._last_loss == ctl._last_loss
    # continuing both yields the identical lock + rejection set
    for c in (ctl, clone):
        _probe_sweep(c, times=[4.0, 1.0, 1.0], losses=[9.7, 10.7, 11.7])
    assert clone.locked == ctl.locked
    assert clone.rejected == ctl.rejected
    # a pre-loss-guard checkpoint (no loss keys) restores cleanly
    legacy = {k: v for k, v in st.items()
              if not k.startswith(("loss_", "last_")) and k != "rejected"}
    old = AggregationController([(0.9, 0)])
    old.restore_state(legacy)
    assert old.loss_delta_means() == [None, None, None]
    assert old.current() == ctl.settings[1]
