"""Vectorized fleet tables (core/fleet.py) + the fleet-backed driver.

Covers the ISSUE-10 tentpole end to end:

  * Fleet.table1 is the exact vectorized dual of make_device_grid —
    same rng stream, bit-identical per-cid devices on both composition
    paths;
  * seeded cohort sampling: deterministic in (seed, round), distinct
    cids, never a dead device, O(P) fallback when availability is low;
  * churn conservation: the dead-set evolves by the (seed, round)
    trace only, rejoins return exactly the killed cids;
  * diurnal availability: duty-cycle fraction realized over a period;
  * small-N equivalence golden: the fleet driver reproduces the object
    driver's per-round commits, comm bytes and final clock bit-for-bit
    on sync AND semi-async pipelined paths;
  * cluster-quorum properties: flat == 1 cluster == P clusters
    (degeneracy), hierarchical close never violates the staleness cap,
    exactly-once ledger under churn;
  * checkpoint: fleet state round-trips through JSON inside the driver
    snapshot and replays the identical availability trace.
"""
import json

import numpy as np
import pytest

from repro.comm import CommChannel
from repro.core.driver import AnalyticCost, RoundDriver
from repro.core.fleet import Fleet
from repro.core.scheduler import MinTimeScheduler
from repro.core.simulation import SERVER_FLOPS, make_device_grid
from repro.core.split import SplitPlan

PLAN = SplitPlan(n_units=8, split_points=(1, 2, 4))


def _rand_costs(rng):
    out = {}
    for s in PLAN.split_points:
        out[s] = dict(wc_size=float(rng.uniform(1e4, 2e6)),
                      feat_size=float(rng.uniform(1e2, 2e4)),
                      fc=float(rng.uniform(1e7, 3e9)),
                      fs=float(rng.uniform(1e7, 3e9)))
    return out


def _cost(p=32):
    ch = CommChannel(codec="fp32", latency=0.01,
                     uplink_capacity=2e7, downlink_capacity=2e7)
    return AnalyticCost(ch, _rand_costs(np.random.default_rng(7)), p=p)


# ---------------------------------------------------------------------------
# table construction
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("composition",
                         [None, {"high": 5, "mid": 3, "low": 2}])
@pytest.mark.parametrize("n", [1, 9, 64, 257])
def test_table1_matches_object_grid(n, composition):
    fleet = Fleet.table1(n, seed=11, composition=composition)
    devices = make_device_grid(n, seed=11, composition=composition)
    assert fleet.population == n
    for d in devices:
        fd = fleet.device(d.cid)
        assert (fd.cid, fd.comp, fd.rate) == (d.cid, d.comp, d.rate)


def test_from_devices_round_trip():
    devices = make_device_grid(12, seed=5)
    fleet = Fleet.from_devices(devices)
    assert all(fleet.device(d.cid) == d for d in devices)
    with pytest.raises(ValueError):
        Fleet.from_devices(devices[1:])          # non-contiguous cids


def test_table_memory_is_flat_arrays():
    fleet = Fleet.table1(10_000, seed=0)
    # 4 float64 tables -> 32 B/device; the benchmark asserts <= 64
    assert fleet.nbytes == 4 * 8 * 10_000


def test_eq1_times_matches_scalar_formula():
    fleet = Fleet.table1(40, seed=2)
    kw = dict(wc_size=5e5, feat_size=3e3, p=32.0, fc=2e8, fs=4e8)
    t = fleet.eq1_times([3, 17, 39], **kw)
    for got, cid in zip(t, (3, 17, 39)):
        d = fleet.device(cid)
        want = ((2 * kw["wc_size"] + 2 * kw["p"] * kw["feat_size"])
                / d.rate + kw["fc"] / d.comp + kw["fs"] / SERVER_FLOPS)
        assert abs(got - want) <= 1e-9 * want


# ---------------------------------------------------------------------------
# sampling, churn, availability
# ---------------------------------------------------------------------------
def test_sample_cohort_deterministic_and_distinct():
    a = Fleet.table1(1_000, seed=4)
    b = Fleet.table1(1_000, seed=4)
    for r in range(5):
        ca, cb = a.sample_cohort(r, 32), b.sample_cohort(r, 32)
        assert ca == cb                          # (seed, round) replay
        assert len(set(ca)) == 32
        assert all(0 <= c < 1_000 for c in ca)
    assert a.sample_cohort(0, 32) != a.sample_cohort(1, 32)
    assert len(Fleet.table1(8, seed=0).sample_cohort(0, 50)) == 8  # clamp


def test_dead_devices_never_sampled_under_churn():
    fleet = Fleet.table1(400, seed=6, churn_kill_prob=0.05,
                         churn_rejoin_prob=0.3)
    for r in range(30):
        cohort = fleet.sample_cohort(r, 24)
        dead = fleet.dead_set()
        assert not dead.intersection(cohort)
        # the sparse path and the dense mask must agree
        mask = fleet.availability_mask(r)
        assert all(mask[c] for c in cohort)
        assert not any(mask[c] for c in dead)
    assert fleet.dead_set()                      # churn actually ran


def test_churn_trace_is_seed_deterministic():
    mk = lambda: Fleet.table1(300, seed=9, churn_kill_prob=0.1,
                              churn_rejoin_prob=0.5)
    a, b = mk(), mk()
    for r in range(12):
        a.sample_cohort(r, 10)
    b.sample_cohort(11, 10)                      # lazy catch-up path
    assert a.dead_set() == b.dead_set()


def test_diurnal_duty_fraction():
    fleet = Fleet.table1(2_000, seed=1, diurnal_period=8,
                         diurnal_duty=0.5)
    fracs = [fleet.availability_mask(r).mean() for r in range(8)]
    assert abs(np.mean(fracs) - 0.5) < 0.05
    cohort = fleet.sample_cohort(3, 64)
    assert all(fleet.availability_mask(3)[c] for c in cohort)


def test_sampling_falls_back_when_availability_is_scarce():
    fleet = Fleet.table1(64, seed=3, churn_rejoin_prob=0.0)
    for c in range(60):                          # only 4 survivors
        fleet.kill(c)
    cohort = fleet.sample_cohort(0, 16)
    assert sorted(cohort) == [60, 61, 62, 63]


def test_state_round_trip_replays_identical_trace():
    mk = lambda: Fleet.table1(500, seed=13, churn_kill_prob=0.08,
                              churn_rejoin_prob=0.4, diurnal_period=6,
                              diurnal_duty=0.8)
    a = mk()
    for r in range(6):
        a.sample_cohort(r, 20)
    a.note_residual(17, 123.5)
    st = json.loads(json.dumps(a.export_state()))
    b = mk()
    b.restore_state(st)
    assert b.dead_set() == a.dead_set()
    assert b.residual_mass[17] == 123.5
    for r in range(6, 12):
        assert a.sample_cohort(r, 20) == b.sample_cohort(r, 20)
    with pytest.raises(ValueError):
        Fleet.table1(10, seed=13).restore_state(st)  # population mismatch


# ---------------------------------------------------------------------------
# fleet-backed driver: equivalence golden + hierarchy properties
# ---------------------------------------------------------------------------
def _cohorts(P, rounds, k, seed=3):
    sampler = Fleet.table1(P, seed=seed)
    return [sampler.sample_cohort(r, k) for r in range(rounds)]


@pytest.mark.parametrize("mode,pipeline",
                         [("sync", False), ("semi_async", True)])
def test_fleet_driver_matches_object_driver(mode, pipeline):
    """The equivalence golden: identical cohorts + identical warm-up
    set -> the fleet driver IS the object driver (clock, per-round
    commits, comm bytes) on fp32."""
    P, rounds, k = 24, 6, 8
    cohorts = _cohorts(P, rounds, k)

    devs = make_device_grid(P, seed=3)
    d_obj = RoundDriver(MinTimeScheduler(PLAN), _cost(), devs,
                        mode=mode, pipeline=pipeline,
                        quorum=0.5, staleness_cap=2)
    fl = Fleet.table1(P, seed=3)
    d_flt = RoundDriver(MinTimeScheduler(PLAN), _cost(), [], fleet=fl,
                        mode=mode, pipeline=pipeline,
                        quorum=0.5, staleness_cap=2,
                        warmup_devices=fl.devices_for(range(P)))
    for r in range(rounds):
        a = d_obj.run_round([devs[c] for c in cohorts[r]])
        b = d_flt.run_round(cohorts[r])
        assert a.committed == b.committed
        assert a.splits == b.splits
    d_obj.flush()
    d_flt.flush()
    assert d_obj.clock == d_flt.clock
    assert d_obj.comm == d_flt.comm


def test_cluster_degeneracies_are_bit_equal():
    """clusters <= 1 and one-device-per-cluster both degenerate to the
    flat quorum close — same clock to the bit."""
    P, rounds, k = 24, 5, 8
    cohorts = _cohorts(P, rounds, k)
    clocks = []
    for clusters, cq in ((0, 1.0), (1, 0.7), (P, 0.7)):
        fl = Fleet.table1(P, seed=3, clusters=clusters)
        drv = RoundDriver(MinTimeScheduler(PLAN), _cost(), [], fleet=fl,
                          mode="semi_async", pipeline=True,
                          quorum=0.6, staleness_cap=2,
                          clusters=clusters, cluster_quorum=cq)
        for r in range(rounds):
            drv.run_round(cohorts[r])
        drv.flush()
        clocks.append(drv.clock)
    assert clocks[0] == clocks[1] == clocks[2]


def test_hierarchical_quorum_properties():
    """Real hierarchy (4 clusters, partial cluster quorum): commits
    never exceed the staleness cap, the ledger stays exactly-once, and
    the partial-quorum close is never slower than the full barrier."""
    P, rounds, k = 32, 8, 12
    cohorts = _cohorts(P, rounds, k, seed=5)

    def drive(cq):
        fl = Fleet.table1(P, seed=5, clusters=4)
        drv = RoundDriver(MinTimeScheduler(PLAN), _cost(), [], fleet=fl,
                          mode="semi_async", pipeline=True,
                          quorum=0.6, staleness_cap=2,
                          clusters=4, cluster_quorum=cq)
        stale = []
        for r in range(rounds):
            rec = drv.run_round(cohorts[r])
            stale += list(rec.staleness.values())
        drv.flush()
        assert drv.n_dispatched == drv.n_committed + drv.n_abandoned
        return drv.clock, stale

    hier, stale = drive(0.7)
    full, _ = drive(1.0)
    assert all(0 <= v <= 2 for v in stale)
    assert hier <= full + 1e-9


def test_driver_materializes_only_sampled_devices():
    fl = Fleet.table1(5_000, seed=1, clusters=8)
    drv = RoundDriver(MinTimeScheduler(PLAN), _cost(), [], fleet=fl,
                      mode="semi_async", pipeline=True,
                      quorum=0.6, staleness_cap=2, cluster_quorum=0.8)
    for r in range(3):
        drv.run_round(fl.sample_cohort(r, 16))
    drv.flush()
    assert len(drv._dev_by_id) <= 3 * 16


def test_driver_syncs_cluster_topology_onto_fleet():
    fl = Fleet.table1(20, seed=0, clusters=4)
    drv = RoundDriver(MinTimeScheduler(PLAN), _cost(), [], fleet=fl)
    assert drv.clusters == 4                     # fleet's knob adopted
    fl2 = Fleet.table1(20, seed=0, clusters=4)
    drv2 = RoundDriver(MinTimeScheduler(PLAN), _cost(), [], fleet=fl2,
                       clusters=6)
    assert drv2.clusters == 6 and fl2.clusters == 6  # driver knob wins


def test_driver_checkpoint_carries_fleet_state():
    """Snapshot mid-run, restore into a FRESH driver + fleet, and the
    continuation is bit-identical (churn trace, dead-set, residual
    table, clock)."""
    def mk():
        fl = Fleet.table1(200, seed=21, clusters=4,
                          churn_kill_prob=0.1, churn_rejoin_prob=0.5)
        drv = RoundDriver(MinTimeScheduler(PLAN), _cost(), [], fleet=fl,
                          mode="semi_async", pipeline=True,
                          quorum=0.6, staleness_cap=2,
                          cluster_quorum=0.75)
        return fl, drv

    fl_a, a = mk()
    for r in range(4):
        a.run_round(fl_a.sample_cohort(r, 12))
    st = json.loads(json.dumps(a.export_state()))
    assert "fleet" in st

    fl_b, b = mk()
    b.restore_state(st)
    assert fl_b.dead_set() == fl_a.dead_set()
    for r in range(4, 8):
        ca, cb = fl_a.sample_cohort(r, 12), fl_b.sample_cohort(r, 12)
        assert ca == cb
        ra, rb = a.run_round(ca), b.run_round(cb)
        assert ra.committed == rb.committed
    a.flush()
    b.flush()
    assert a.clock == b.clock
