"""Core S²FL mechanics: split plans, scheduler, balance grouping (Eq. 2),
Algorithm-1 aggregation — unit + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.aggregation import ClientState, aggregate, fedavg_aggregate
from repro.core.balance import (balance_permutation, eq2_distance,
                                exhaustive_groups, greedy_groups,
                                group_distance, label_histogram)
from repro.core.scheduler import FixedSplitScheduler, SlidingSplitScheduler
from repro.core.simulation import Device, make_device_grid
from repro.core.split import SplitPlan, default_plan
from repro.configs import get_config, make_reduced
from repro.models import SplitModel

KEY = jax.random.PRNGKey(3)


# ---------------------------------------------------------------------------
# split plan
# ---------------------------------------------------------------------------
@given(st.integers(min_value=2, max_value=80), st.integers(2, 4))
def test_default_plan_properties(n_units, k):
    plan = default_plan(n_units, k=k)
    assert 1 <= plan.k <= k
    assert all(0 < s <= n_units for s in plan.split_points)
    assert plan.split_points == tuple(sorted(set(plan.split_points)))
    if n_units > k:
        assert plan.k == k


# ---------------------------------------------------------------------------
# Eq. 2 grouping
# ---------------------------------------------------------------------------
def test_eq2_distance_uniform_is_zero():
    assert eq2_distance(np.ones(10) * 7) < 1e-12


def test_eq2_distance_skewed_is_large():
    h = np.zeros(10)
    h[3] = 100
    assert eq2_distance(h) > 0.9


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_greedy_groups_partition_property(seed):
    """Grouping is a partition, and grouped distance <= mean singleton
    distance (combining complementary skews can only help on average)."""
    rng = np.random.default_rng(seed)
    x, n_classes = 8, 6
    hists = rng.integers(0, 50, size=(x, n_classes)).astype(float)
    groups = greedy_groups(hists, group_size=2)
    flat = sorted(c for g in groups for c in g)
    assert flat == list(range(x))
    mean_grouped = np.mean([group_distance(hists, g) for g in groups])
    mean_single = np.mean([eq2_distance(h) for h in hists])
    assert mean_grouped <= mean_single + 1e-9


def test_greedy_close_to_exhaustive_on_complementary_data():
    """Clients with complementary halves of the label space: optimal
    pairing reaches ~0; greedy must find it (or near)."""
    n_classes = 10
    hists = []
    for i in range(3):
        a = np.zeros(n_classes)
        a[:5] = 10 + i
        b = np.zeros(n_classes)
        b[5:] = 10 + i
        hists += [a, b]
    hists = np.array(hists)
    greedy = greedy_groups(hists, group_size=2)
    best = exhaustive_groups(hists, group_size=2)
    g_d = sum(group_distance(hists, g) for g in greedy)
    b_d = sum(group_distance(hists, g) for g in best)
    assert g_d <= b_d + 0.05
    assert g_d < 0.05                     # complementary pairs -> uniform


def test_balance_permutation_layout():
    perm = balance_permutation([10, 11, 12, 13],
                               [(11, 13), (12, 10)], per_client=2)
    # group (11,13) first: rows 2,3 then 6,7; group (12,10): 4,5 then 0,1
    assert perm.tolist() == [2, 3, 6, 7, 4, 5, 0, 1]
    assert sorted(perm.tolist()) == list(range(8))


def test_label_histogram():
    h = label_histogram(np.array([0, 0, 3, 9]), 10)
    assert h[0] == 2 and h[3] == 1 and h[9] == 1 and h.sum() == 4


# ---------------------------------------------------------------------------
# scheduler (§3.1)
# ---------------------------------------------------------------------------
def test_scheduler_warmup_traverses_all_splits():
    plan = SplitPlan(n_units=8, split_points=(1, 2, 4))
    sched = SlidingSplitScheduler(plan)
    seen = set()
    for r in range(plan.k):
        sel = sched.select([0, 1, 2])
        assert len(set(sel.values())) == 1
        seen.add(next(iter(sel.values())))
        for c, s in sel.items():
            sched.observe(c, s, t=1.0)
        sched.end_round()
    assert seen == {1, 2, 4}


def test_scheduler_equalizes_straggler_times():
    """Fast device should get a larger split than the slow one after
    warm-up, when time grows with split size."""
    plan = SplitPlan(n_units=8, split_points=(1, 2, 4))
    sched = SlidingSplitScheduler(plan)
    speed = {0: 4.0, 1: 1.0}              # device 0 is 4x faster
    for r in range(plan.k):
        sel = sched.select([0, 1])
        for c, s in sel.items():
            sched.observe(c, s, t=s / speed[c])
        sched.end_round()
    sel = sched.select([0, 1])
    assert sel[0] > sel[1]
    t0 = sel[0] / speed[0]
    t1 = sel[1] / speed[1]
    # chosen splits bring times closer than the worst-case pairing
    assert abs(t0 - t1) <= abs(plan.largest() / speed[1]
                               - plan.smallest() / speed[0])


def test_fixed_scheduler_is_largest_split():
    plan = SplitPlan(n_units=8, split_points=(1, 2, 4))
    sched = FixedSplitScheduler(plan)
    assert set(sched.select([0, 1]).values()) == {4}


# ---------------------------------------------------------------------------
# Eq. 1 simulation
# ---------------------------------------------------------------------------
def test_eq1_straggler_vs_fast_device():
    from repro.core.simulation import BYTES_PER_ELEM, device_round_time_bytes

    def t_of(dev, *, wc_size, feat_size, p, fc, fs):
        nbytes = (2.0 * wc_size + 2.0 * p * feat_size) * BYTES_PER_ELEM
        return device_round_time_bytes(dev, comm_bytes=nbytes, fc=fc, fs=fs)

    slow = Device(0, comp=5e9, rate=1e6)
    fast = Device(1, comp=2e10, rate=5e6)
    t_slow = t_of(slow, wc_size=1e6, feat_size=1e4, p=32, fc=1e10, fs=1e10)
    t_fast = t_of(fast, wc_size=1e6, feat_size=1e4, p=32, fc=1e10, fs=1e10)
    assert t_slow > t_fast
    # smaller portion shrinks the slow device's time
    t_slow_small = t_of(slow, wc_size=1e5, feat_size=1e4, p=32,
                        fc=1e9, fs=1.9e10)
    assert t_slow_small < t_slow
    # the byte path reproduces the seed's element-based Eq.-1 numbers
    # (the deprecated element helpers are gone; this inlines their math)
    legacy = (2.0 * 1e6 + 2.0 * 32 * 1e4) / slow.rate \
        + 1e10 / slow.comp + 1e10 / 5e10
    assert legacy == pytest.approx(t_slow)


def test_device_grid_covers_table1():
    devs = make_device_grid(18)
    comps = {d.comp for d in devs}
    rates = {d.rate for d in devs}
    assert comps == {5e9, 1e10, 2e10}
    assert rates == {1e6, 2e6, 5e6}


# ---------------------------------------------------------------------------
# Algorithm 1 aggregation
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def small_model():
    return SplitModel(make_reduced(get_config("internlm2-1.8b")))


def test_aggregate_identity(small_model):
    """All sources identical -> aggregate is identity."""
    p = small_model.init(KEY)
    clients = [ClientState(cid=i, params=p, split=1, data_size=float(i + 1),
                           group=0) for i in range(3)]
    out = aggregate(small_model, clients, {0: p})
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(p)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=1e-6)


def test_aggregate_sources_by_split(small_model):
    """A block below the split comes from clients; above, from the server
    copy — with |D_i| weighting (Alg. 1 lines 3-17)."""
    model = small_model
    ones = jax.tree.map(jnp.ones_like, model.init(KEY))
    twos = jax.tree.map(lambda x: 2 * jnp.ones_like(x), ones)
    fives = jax.tree.map(lambda x: 5 * jnp.ones_like(x), ones)
    # two clients, split=1: client trains embed+block:0; server the rest
    clients = [
        ClientState(cid=0, params=ones, split=1, data_size=1.0, group=0),
        ClientState(cid=1, params=twos, split=1, data_size=3.0, group=0),
    ]
    out = aggregate(model, clients, {0: fives})
    # block:0 = (1*1 + 2*3)/4 = 1.75 ; block:1 = 5 (server copy both times)
    b0 = out["blocks"][0]["norm1"]["scale"]
    b1 = out["blocks"][1]["norm1"]["scale"]
    np.testing.assert_allclose(np.asarray(b0), 1.75, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(b1), 5.0, rtol=1e-6)
    # embed is client-side, head/final_norm server-side
    np.testing.assert_allclose(np.asarray(out["embed"]["tok"])[0, 0], 1.75,
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out["final_norm"]["scale"])[0],
                               5.0, rtol=1e-6)


def test_aggregate_mixed_splits(small_model):
    """Different splits: block:1 aggregates client-1's copy with group-0's
    server copy."""
    model = small_model
    ones = jax.tree.map(jnp.ones_like, model.init(KEY))
    twos = jax.tree.map(lambda x: 2 * jnp.ones_like(x), ones)
    fives = jax.tree.map(lambda x: 5 * jnp.ones_like(x), ones)
    clients = [
        ClientState(cid=0, params=ones, split=1, data_size=1.0, group=0),
        ClientState(cid=1, params=twos, split=2, data_size=1.0, group=1),
    ]
    out = aggregate(model, clients, {0: fives, 1: fives})
    b1 = out["blocks"][1]["norm1"]["scale"]
    np.testing.assert_allclose(np.asarray(b1), (5.0 + 2.0) / 2, rtol=1e-6)


@given(st.lists(st.floats(0.1, 10.0), min_size=2, max_size=4))
@settings(max_examples=10, deadline=None)
def test_fedavg_aggregate_convex(weights):
    trees = [{"w": jnp.full((3,), float(i))} for i in range(len(weights))]
    out = fedavg_aggregate(trees, weights)
    lo, hi = 0.0, float(len(weights) - 1)
    assert float(out["w"][0]) >= lo - 1e-6
    assert float(out["w"][0]) <= hi + 1e-6
    expect = sum(w * i for i, w in enumerate(weights)) / sum(weights)
    np.testing.assert_allclose(float(out["w"][0]), expect, rtol=1e-5)
