"""RoundDriver — the single round-loop implementation (core/driver.py).

Covers: golden equivalence with the pre-refactor inline loop (fixed
seed, fp32/static, sync — the bit-exactness contract the phase-split
refactor must preserve), the semi_async event-queue clock bounds
(wall-clock <= sync on the static Table-1 grid; staleness never exceeds
the cap; cap=0 degenerates to sync), the phase pipeline (golden clock,
pipelined <= phase-sequential <= sync ordering, phase bookkeeping,
contention/latency pricing, sync pipelined training equivalence), the
predictive (link-forecasting) split selection, cost-model plumbing, and
the engine running full semi_async / pipelined training rounds for
real."""
import numpy as np
import pytest

from repro.comm import CommChannel, LinkTrace, StaticLink
from repro.core.driver import (AnalyticCost, CallableCost, RoundDriver)
from repro.core.scheduler import FixedSplitScheduler, SlidingSplitScheduler
from repro.core.simulation import make_device_grid
from repro.core.split import SplitPlan

# Synthetic per-split Eq.-1 quantities (model-free so the goldens do not
# depend on XLA's cost analysis): wc grows with the split, the cut-layer
# feature shrinks — the VGG16-like regime where sliding splits help.
PLAN = SplitPlan(n_units=8, split_points=(1, 2, 4))
COSTS = {1: dict(wc_size=2.0e5, feat_size=8.0e3, fc=6.0e8, fs=2.4e9),
         2: dict(wc_size=6.0e5, feat_size=4.0e3, fc=1.2e9, fs=1.8e9),
         4: dict(wc_size=1.8e6, feat_size=2.0e3, fc=2.4e9, fs=6.0e8)}
P = 64

# Captured from the pre-refactor inline warm-up/select/observe loop
# (benchmarks/time_comm.py simulate_comm semantics) on exactly the
# setup _drive() builds: 12 Table-1 devices (seed 0), 5 participants per
# round, 10 rounds, fp32 codec, static link.
GOLDEN_CLOCK = 149.97601899999998
GOLDEN_COMM = 423424400.0
GOLDEN_LAST_SEL = {2: 4, 3: 2, 4: 2, 7: 2, 11: 1}
# Same setup through the phase pipeline (semi_async, cap=1, quorum=0.5),
# after flush() drains the straggler tail and the last downloads. Same
# wire bytes; the clock is the pipelined event timeline.
GOLDEN_PIPE_CLOCK = 64.95280709999999


def _drive(mode="sync", rounds=10, link=None, staleness_cap=1,
           quorum=0.5, seed=0, n_devices=12, per_round=5,
           pipeline=False, latency=0.0, uplink_capacity=0.0,
           downlink_capacity=0.0, server_concurrency=0,
           gate_redispatch=False, latency_dist="constant",
           latency_jitter=0.5, latency_seed=0):
    devices = make_device_grid(n_devices, seed=seed)
    ch = CommChannel(codec="fp32", link=link or StaticLink(),
                     latency=latency, uplink_capacity=uplink_capacity,
                     downlink_capacity=downlink_capacity,
                     latency_dist=latency_dist,
                     latency_jitter=latency_jitter,
                     latency_seed=latency_seed)
    drv = RoundDriver(SlidingSplitScheduler(PLAN),
                      AnalyticCost(ch, COSTS, p=P), devices,
                      mode=mode, staleness_cap=staleness_cap,
                      quorum=quorum, pipeline=pipeline,
                      server_concurrency=server_concurrency,
                      gate_redispatch=gate_redispatch)
    rng = np.random.default_rng(seed)
    recs = []
    for r in range(rounds):
        part = rng.choice(devices, size=per_round, replace=False)
        recs.append(drv.run_round(part))
    return drv, recs


# ---------------------------------------------------------------------------
# golden equivalence: driver == the pre-refactor inline loop
# ---------------------------------------------------------------------------
def test_driver_matches_prerefactor_inline_loop_golden():
    drv, recs = _drive()
    assert drv.clock == pytest.approx(GOLDEN_CLOCK, rel=1e-12)
    assert drv.comm == pytest.approx(GOLDEN_COMM, rel=1e-12)
    sel = {int(k): int(v) for k, v in recs[-1].splits.items()}
    assert sel == GOLDEN_LAST_SEL
    # sync bookkeeping: every round commits exactly its own work
    assert all(r.pending == 0 for r in recs)
    assert all(set(r.committed) == set(r.splits) for r in recs)
    assert all(v == 0 for r in recs for v in r.staleness.values())
    # per-round times/clock are self-consistent
    assert drv.clock == pytest.approx(sum(r.round_time for r in recs))
    for r in recs:
        assert r.round_time == pytest.approx(max(r.times.values()))


# ---------------------------------------------------------------------------
# semi_async event queue
# ---------------------------------------------------------------------------
def test_semi_async_wall_clock_never_exceeds_sync():
    """On the static Table-1 grid the aggregation window closes at or
    before the sync barrier every round, so the event-timeline clock is
    a lower bound — and with 12 heterogeneous devices a strict win."""
    sync, _ = _drive(mode="sync")
    semi, recs = _drive(mode="semi_async", staleness_cap=1)
    assert semi.clock <= sync.clock + 1e-9
    assert semi.clock < sync.clock          # stragglers really overlap
    assert semi.comm == pytest.approx(sync.comm)   # same wire traffic
    assert any(r.pending > 0 for r in recs)        # events were in flight


def test_semi_async_staleness_bounded_by_cap():
    for cap in (1, 2, 3):
        drv, recs = _drive(mode="semi_async", staleness_cap=cap,
                           quorum=0.4, rounds=12)
        lags = [v for r in recs for v in r.staleness.values()]
        assert lags and max(lags) <= cap
        if cap == 1:
            assert max(lags) == 1           # stragglers did arrive late
        # flush commits whatever was still pending at shutdown
        drv.flush()
        assert not drv._pending


def test_staleness_cap_zero_degenerates_to_sync():
    sync, srecs = _drive(mode="sync")
    zero, zrecs = _drive(mode="semi_async", staleness_cap=0)
    assert zero.clock == pytest.approx(sync.clock)
    for a, b in zip(srecs, zrecs):
        assert a.round_time == pytest.approx(b.round_time)
        assert set(a.committed) == set(b.committed)


def test_driver_validates_knobs():
    devices = make_device_grid(3, seed=0)
    cost = CallableCost(lambda c, s: 1.0)
    with pytest.raises(ValueError):
        RoundDriver(SlidingSplitScheduler(PLAN), cost, devices,
                    mode="fully_async")
    with pytest.raises(ValueError):
        RoundDriver(SlidingSplitScheduler(PLAN), cost, devices,
                    staleness_cap=-1)
    with pytest.raises(ValueError):
        RoundDriver(SlidingSplitScheduler(PLAN), cost, devices, quorum=0.0)
    with pytest.raises(ValueError):
        # FixedSplitScheduler has no forecast hook
        RoundDriver(FixedSplitScheduler(PLAN), cost, devices,
                    predictive=True)


def test_empty_round_is_a_noop_on_the_clock():
    drv, _ = _drive(rounds=2)
    clock, comm = drv.clock, drv.comm
    rec = drv.run_round([])
    assert drv.clock == clock and drv.comm == comm
    assert rec.round_time == 0.0 and rec.committed == ()


def test_flush_with_nothing_pending_is_a_noop():
    # sync commits everything inside its own round: flush finds nothing
    drv, _ = _drive(rounds=3)
    clock, comm = drv.clock, drv.comm
    committed, staleness = drv.flush()
    assert committed == [] and staleness == {}
    assert drv.clock == clock and drv.comm == comm


def test_flush_twice_second_is_a_noop():
    drv, _ = _drive(mode="semi_async", pipeline=True)
    committed, _ = drv.flush()
    assert committed                      # the straggler tail drained
    clock = drv.clock
    again, stale = drv.flush()
    assert again == [] and stale == {}
    assert drv.clock == clock             # no double-advance


# ---------------------------------------------------------------------------
# phase pipeline (upload / server compute / download)
# ---------------------------------------------------------------------------
def test_pipeline_golden_clock_and_same_wire_bytes():
    """The pipelined event timeline on the golden setup: deterministic
    clock, identical wire traffic (phases re-slice the round, they never
    change what crosses the wire)."""
    drv, recs = _drive(mode="semi_async", pipeline=True)
    drv.flush()
    assert drv.clock == pytest.approx(GOLDEN_PIPE_CLOCK, rel=1e-12)
    assert drv.comm == pytest.approx(GOLDEN_COMM, rel=1e-12)
    assert any(r.phases for r in recs)


def test_pipelined_le_sequential_le_sync():
    """Commits move to the end of server compute, so after flushing the
    download tail the pipelined wall-clock is a lower bound on the
    phase-sequential one, which lower-bounds sync (static link)."""
    sync, _ = _drive(mode="sync")
    seq, _ = _drive(mode="semi_async")
    pipe, _ = _drive(mode="semi_async", pipeline=True)
    seq.flush(), pipe.flush()
    assert pipe.clock < seq.clock       # overlap really bought time
    assert seq.clock <= sync.clock + 1e-9
    assert pipe.comm == pytest.approx(sync.comm)


def test_pipeline_phase_bookkeeping():
    """Per-device phase durations are positive, chain to the device's
    full Eq.-1 round time (uncontended), and the download heap drains
    by flush()."""
    drv, recs = _drive(mode="semi_async", pipeline=True)
    assert any(r.downloads > 0 for r in recs)   # downloads really drain
    for r in recs:                              # in the background
        for c, ph in r.phases.items():
            assert ph["up"] > 0 and ph["srv"] > 0 and ph["down"] > 0
            assert ph["up"] + ph["srv"] + ph["down"] \
                == pytest.approx(r.times[c])
    drv.flush()
    assert not drv._downloads and not drv._pending


def test_pipeline_sync_barrier_still_commits_everything():
    drv, recs = _drive(mode="sync", pipeline=True)
    assert all(set(r.committed) == set(r.splits) for r in recs)
    assert all(v == 0 for r in recs for v in r.staleness.values())


def test_pipeline_contention_and_latency_price_the_clock():
    """A finite shared ingress stretches overlapping uploads; a
    per-message latency adds 4 * latency to every device-round in BOTH
    the atomic and the phase paths (consistent pricing)."""
    free, _ = _drive(mode="semi_async", pipeline=True)
    free.flush()
    jam, _ = _drive(mode="semi_async", pipeline=True,
                    uplink_capacity=2e6)
    jam.flush()
    assert jam.clock > free.clock       # uploads really contended
    assert jam.comm == pytest.approx(free.comm)

    devices = make_device_grid(3, seed=0)
    lat = 0.25
    ch0 = CommChannel(codec="fp32")
    ch1 = CommChannel(codec="fp32", latency=lat)
    c0 = AnalyticCost(ch0, COSTS, p=P)
    c1 = AnalyticCost(ch1, COSTS, p=P)
    t0, _ = c0.time_and_bytes(devices[0], 2, 0.0)
    t1, _ = c1.time_and_bytes(devices[0], 2, 0.0)
    assert t1 == pytest.approx(t0 + 4 * lat)
    p0 = c0.phase_cost(devices[0], 2, 0.0)
    p1 = c1.phase_cost(devices[0], 2, 0.0)
    chained0 = p0.t_pre + p0.up_bytes / p0.up_rate + p0.t_srv + p0.t_down
    chained1 = p1.t_pre + p1.up_bytes / p1.up_rate + p1.t_srv + p1.t_down
    assert chained0 == pytest.approx(t0)        # phases re-slice Eq. 1
    assert chained1 == pytest.approx(t1)
    assert p0.total_bytes == pytest.approx(
        c0.time_and_bytes(devices[0], 2, 0.0)[1])


def test_forecast_sees_contention_adjusted_rate():
    """With a bounded shared ingress the predictive forecast prices the
    round with min(link rate, capacity / cohort size) — a fuller round
    forecasts slower."""
    devices = make_device_grid(3, seed=0)
    cost = AnalyticCost(CommChannel(codec="fp32", uplink_capacity=1e6),
                        COSTS, p=P)
    alone = cost.forecast_time(devices[0], 2, 0.0, 10.0, load=1)
    crowded = cost.forecast_time(devices[0], 2, 0.0, 10.0, load=8)
    assert crowded > alone
    # uncontended channel: load changes nothing
    cost0 = AnalyticCost(CommChannel(codec="fp32"), COSTS, p=P)
    assert cost0.forecast_time(devices[0], 2, 0.0, 10.0, load=8) \
        == pytest.approx(cost0.forecast_time(devices[0], 2, 0.0, 10.0))


# ---------------------------------------------------------------------------
# finite resources: server slots, duplex contention, cross-window carry,
# re-dispatch gating, per-(device, round) latency draws
# ---------------------------------------------------------------------------
def test_resource_knobs_at_defaults_reproduce_pipeline_golden():
    """Golden regression for the resource refactor: with every new knob
    pinned to its default (unbounded server, uncontended egress, no
    gating, constant latency) the pipelined event timeline reproduces
    the pre-refactor clock and wire bytes BIT-exactly."""
    drv, recs = _drive(mode="semi_async", pipeline=True,
                       downlink_capacity=0.0, server_concurrency=0,
                       gate_redispatch=False, latency_dist="constant")
    drv.flush()
    assert drv.clock == pytest.approx(GOLDEN_PIPE_CLOCK, rel=1e-12)
    assert drv.comm == pytest.approx(GOLDEN_COMM, rel=1e-12)


def test_server_slots_serialize_group_backwards():
    """A single server slot forces the overlapping group backwards into
    a FIFO queue, so the flushed clock grows strictly; srv phase
    durations then include the queue wait (>= the pure compute time)."""
    free, _ = _drive(mode="semi_async", pipeline=True)
    free.flush()
    jam, recs = _drive(mode="semi_async", pipeline=True,
                       server_concurrency=1)
    jam.flush()
    assert jam.clock > free.clock
    # (comm may differ: the sliding scheduler adapts its splits to the
    # queue-stretched times it observes; bytes-invariance on a FIXED
    # schedule is property-tested in test_driver_properties.py)
    waits = [ph["srv"] for r in recs for ph in r.phases.values()]
    assert max(waits) > min(waits)               # someone really queued


def test_downlink_contention_slows_and_conserves():
    """A finite shared egress stretches overlapping dfx downloads (the
    same fluid max-min fair schedule as the uplink), slowing the
    flushed clock without changing what crosses the wire — and every
    submitted byte drains by the final clock."""
    free, _ = _drive(mode="semi_async", pipeline=True)
    free.flush()
    jam, _ = _drive(mode="semi_async", pipeline=True,
                    downlink_capacity=5e5)
    jam.flush()
    assert jam.clock > free.clock
    rem = jam._downlink.remaining_at(jam.clock)
    assert sum(rem) == pytest.approx(0.0, abs=1e-6)


def test_gate_redispatch_only_delays():
    """Gating a device's next upload on its own draining download
    removes the overcommit optimism, so the flushed clock can only
    grow — and on the golden setup (downloads routinely outlive the
    aggregation window) it strictly does."""
    free, _ = _drive(mode="semi_async", pipeline=True)
    free.flush()
    gated, _ = _drive(mode="semi_async", pipeline=True,
                      gate_redispatch=True)
    gated.flush()
    assert gated.clock >= free.clock - 1e-9
    assert gated.clock > free.clock        # devices really were re-used


def test_straggler_upload_contends_with_next_cohort():
    """Cross-window carry: contention is no longer solved per dispatch
    cohort. Device 0's huge upload is still in flight when the next
    window dispatches device 1, so device 1's second upload is slowed
    by the carried flow (under the per-cohort model it would finish at
    its solo time)."""
    from repro.core.driver import CallableCost, PhaseCost

    def phases_of(cid, split):
        return PhaseCost(t_pre=0.0,
                         up_bytes=1000.0 if cid == 0 else 10.0,
                         up_rate=10.0, t_srv=0.01, t_down=0.01,
                         total_bytes=0.0)

    cost = CallableCost(lambda c, s: 1.0, phases_of=phases_of)
    cost.shared_uplink_bytes = lambda: 10.0    # shared ingress = one rate
    drv = RoundDriver(FixedSplitScheduler(PLAN), cost, [0, 1],
                      mode="semi_async", staleness_cap=10, quorum=0.4,
                      pipeline=True)
    r0 = drv.run_round([0, 1])      # window closes on device 1's commit
    assert len(r0.committed) == 1
    r1 = drv.run_round([1])         # device 0's upload still in flight
    # solo, device 1 uploads 10 B at min(own rate, capacity) = 10 B/s =
    # 1 s; sharing the ingress max-min fairly with the carried straggler
    # it gets 5 B/s = 2 s
    assert r1.phases[1]["up"] == pytest.approx(2.0)
    drv.flush()
    assert not drv._pending and not drv._flights


def test_rekey_keeps_redispatched_devices_events_separate():
    """Standalone-driver work keys are bare cids, so a device
    re-dispatched while its old commit event still pends REUSES its
    key. The carried-event re-key must match flights by (dispatch
    round, key): the round-0 event keeps its own flight's commit and
    must not inherit the re-dispatched flight's later one."""
    from repro.core.driver import CallableCost, PhaseCost

    def phases_of(cid, split):
        return PhaseCost(t_pre=0.0,
                         up_bytes=100.0 if cid == 0 else 10.0,
                         up_rate=10.0, t_srv=1.0, t_down=0.1,
                         total_bytes=0.0)

    cost = CallableCost(lambda c, s: 1.0, phases_of=phases_of)
    drv = RoundDriver(FixedSplitScheduler(PLAN), cost, [0, 1],
                      mode="semi_async", staleness_cap=3, quorum=0.4,
                      pipeline=True)
    drv.run_round([0, 1])   # dev0: upload 10 s + srv 1 s -> commit 11;
    #                         dev1 commits at 2, closing the window
    drv.run_round([0, 1])   # dev0 re-dispatched while its event pends
    drv.run_round([1])      # triggers the carried-event re-key
    readies = sorted(e.ready for e in drv._pending)
    assert readies[0] == pytest.approx(11.0)   # round-0 commit kept
    drv.flush()
    assert not drv._pending and not drv._flights


def test_semi_async_replay_deterministic_including_latency_draws():
    """A fixed seed replays the semi-async pipelined timeline exactly —
    including the per-(device, round) latency draws (each draw is
    seeded by (latency_seed, cid, round), not by call order). A
    different latency seed changes the draws and the clock."""
    kw = dict(mode="semi_async", pipeline=True, latency=0.2,
              latency_dist="lognormal")
    a, ra = _drive(**kw)
    b, rb = _drive(**kw)
    a.flush(), b.flush()
    assert a.clock == b.clock                 # bit-identical replay
    for x, y in zip(ra, rb):
        assert x.times == y.times
        assert x.splits == y.splits
        assert x.committed == y.committed
    c, _ = _drive(latency_seed=7, **kw)
    c.flush()
    assert c.clock != a.clock
    # constant dist never touches the RNG: identical to the plain-knob
    # timeline regardless of jitter/seed
    d0, _ = _drive(mode="semi_async", pipeline=True, latency=0.2)
    d1, _ = _drive(mode="semi_async", pipeline=True, latency=0.2,
                   latency_jitter=0.9, latency_seed=3)
    d0.flush(), d1.flush()
    assert d0.clock == d1.clock


def test_latency_sampler_properties():
    from repro.comm import LatencySampler

    s = LatencySampler(0.1, "lognormal", jitter=0.4, seed=0)
    assert s.sample(3, 5) == s.sample(3, 5)          # deterministic
    assert s.sample(3, 5) != s.sample(3, 6)          # per-round stream
    assert s.sample(2, 5) != s.sample(3, 5)          # per-device stream
    assert s.mean == 0.1
    draws = [s.sample(c, r) for c in range(40) for r in range(40)]
    assert all(d > 0 for d in draws)
    assert np.mean(draws) == pytest.approx(0.1, rel=0.05)
    u = LatencySampler(0.1, "uniform", jitter=0.5, seed=0)
    udraws = [u.sample(c, r) for c in range(30) for r in range(30)]
    assert all(0.05 - 1e-12 <= d <= 0.15 + 1e-12 for d in udraws)
    assert LatencySampler(0.1, "constant").sample(0, 0) == 0.1
    with pytest.raises(ValueError):
        LatencySampler(0.1, "pareto")
    with pytest.raises(ValueError):
        LatencySampler(-0.1, "uniform")


def test_driver_validates_resource_knobs():
    devices = make_device_grid(3, seed=0)
    cost = CallableCost(lambda c, s: 1.0)
    with pytest.raises(ValueError):
        RoundDriver(SlidingSplitScheduler(PLAN), cost, devices,
                    server_concurrency=-1)
    with pytest.raises(ValueError):
        CommChannel(downlink_capacity=-1.0)
    with pytest.raises(ValueError):
        CommChannel(latency_dist="weibull")


# ---------------------------------------------------------------------------
# predictive (link-forecasting) split selection
# ---------------------------------------------------------------------------
def test_predictive_anticipates_link_fade():
    """A cliff-shaped trace: full rate until t=40, 5% after. The EMA
    table only knows the fast era, so the reactive scheduler keeps
    dispatching as if the link were healthy; the predictive forecast
    prices candidates with the mean rate over the projected completion
    window and switches assignments before the fade actually bites."""
    trace = LinkTrace([0.0, 40.0], [1.0, 0.05], period=1e9,
                      per_device_phase=False)

    def drive(predictive):
        devices = make_device_grid(9, seed=0)
        ch = CommChannel(codec="fp32", link=trace)
        sched = SlidingSplitScheduler(PLAN)
        drv = RoundDriver(sched, AnalyticCost(ch, COSTS, p=P), devices,
                          predictive=predictive)
        sels = []
        for r in range(PLAN.k + 4):
            sels.append(drv.run_round(devices).splits)
        return drv, sels

    reactive, r_sels = drive(False)
    predictive, p_sels = drive(True)
    assert any(r != p for r, p in zip(r_sels, p_sels))


def test_predictive_on_static_link_is_identity():
    """With a static link the mean future rate equals the current rate,
    so predictive selection must not perturb the schedule (fp32/static
    stays the seed regime)."""
    base, brecs = _drive()
    devices = make_device_grid(12, seed=0)
    drv = RoundDriver(SlidingSplitScheduler(PLAN),
                      AnalyticCost(CommChannel(), COSTS, p=P), devices,
                      predictive=True)
    rng = np.random.default_rng(0)
    for r in range(10):
        part = rng.choice(devices, size=5, replace=False)
        rec = drv.run_round(part)
        assert rec.splits == brecs[r].splits
    assert drv.clock == pytest.approx(base.clock)


def test_link_trace_mean_multiplier_exact_integral():
    tr = LinkTrace([0.0, 10.0, 20.0], [1.0, 0.25, 0.5], period=30.0,
                   per_device_phase=False)
    # within one segment
    assert tr.mean_multiplier(2.0, 8.0) == pytest.approx(1.0)
    # spanning two segments: 5s at 1.0 + 5s at 0.25
    assert tr.mean_multiplier(5.0, 15.0) == pytest.approx(0.625)
    # a full period averages to the period mean regardless of phase
    mean = (10 * 1.0 + 10 * 0.25 + 10 * 0.5) / 30.0
    assert tr.mean_multiplier(0.0, 30.0) == pytest.approx(mean)
    assert tr.mean_multiplier(7.0, 37.0) == pytest.approx(mean)
    # wrap across the period boundary: 5s at 0.5 + 5s at 1.0
    assert tr.mean_multiplier(25.0, 35.0) == pytest.approx(0.75)
    # degenerate window falls back to the instantaneous multiplier
    assert tr.mean_multiplier(12.0, 12.0) == pytest.approx(0.25)
    dev = make_device_grid(1, seed=0)[0]
    assert tr.mean_rate(dev, 5.0, 15.0) == pytest.approx(dev.rate * 0.625)


# ---------------------------------------------------------------------------
# the engine drives real training through the same loop
# ---------------------------------------------------------------------------
def _make_engine(dcfg, rounds=4):
    from repro.configs import DriverConfig, get_config
    from repro.core.engine import EngineConfig, S2FLEngine
    from repro.data.partition import federate
    from repro.data.synthetic import make_image_dataset
    from repro.models import SplitModel

    ds = make_image_dataset(300, seed=0)
    fed = federate(ds, 8, alpha=0.3, seed=0)
    model = SplitModel(get_config("resnet8"))
    ecfg = EngineConfig(mode="s2fl", rounds=rounds, clients_per_round=5,
                        batch_size=16, group_size=2, driver=dcfg)
    return S2FLEngine(model, fed, ecfg)


@pytest.mark.slow
def test_engine_semi_async_trains_and_overlaps():
    from repro.configs import DriverConfig

    sync = _make_engine(DriverConfig())
    sync.run(rounds=4)
    semi = _make_engine(DriverConfig(exec_mode="semi_async",
                                     staleness_cap=2, quorum=0.5))
    semi.run(rounds=4)
    # the event timeline can only help the clock on the static link
    assert semi.clock <= sync.clock + 1e-9
    # stale updates really flowed through later windows...
    assert any(h["pending"] > 0 for h in semi.history)
    # ...and none were dropped: run() flushes the in-flight stragglers
    assert not semi._held
    assert all(np.isfinite(h["loss"]) for h in semi.history)
    # same wire traffic either way — only the clock semantics differ
    assert semi.comm == pytest.approx(sync.comm)


@pytest.mark.slow
def test_engine_sync_pipeline_is_a_timing_only_change():
    """Golden regression for the phase split: exec_mode=sync on
    fp32/static trains to the SAME parameters with the pipeline on or
    off (phases re-slice the simulated clock; the training data flow —
    sampling, grouping, codec round-trips, aggregation — is untouched),
    with identical wire bytes and a clock that overlap can only
    shrink."""
    import jax

    from repro.configs import DriverConfig

    sync = _make_engine(DriverConfig())
    sync.run(rounds=4)
    pipe = _make_engine(DriverConfig(pipeline=True))
    pipe.run(rounds=4)
    assert pipe.comm == pytest.approx(sync.comm)
    assert pipe.clock <= sync.clock + 1e-9
    for a, b in zip(jax.tree.leaves(sync.params),
                    jax.tree.leaves(pipe.params)):
        np.testing.assert_allclose(np.asarray(a, np.float64),
                                   np.asarray(b, np.float64), rtol=1e-6)
    # per-round losses identical too (same batches, same updates)
    assert [h["loss"] for h in sync.history] \
        == pytest.approx([h["loss"] for h in pipe.history])
    # the pipelined history carries the per-phase time split
    assert all({"t_upload", "t_server", "t_download"} <= set(h)
               for h in pipe.history)
    # the flush tail (download-only in sync mode: every commit already
    # landed in-window) is folded into the final record, so the history
    # agrees with the driver about the true total wall-clock
    assert pipe.history[-1]["clock"] == pipe.clock
    assert pipe.history[-1]["pending"] == 0


@pytest.mark.slow
def test_engine_trains_under_full_resource_constraints():
    """The whole resource stack through real training: duplex
    contention + 1 server slot + gating + lognormal latency draws.
    Training stays healthy, the clock can only grow vs the free-overlap
    pipeline, and a re-run replays the clock exactly (deterministic
    latency draws included)."""
    from repro.configs import CommConfig, DriverConfig, get_config
    from repro.core.engine import EngineConfig, S2FLEngine
    from repro.data.partition import federate
    from repro.data.synthetic import make_image_dataset
    from repro.models import SplitModel

    def build():
        ds = make_image_dataset(200, seed=0)
        fed = federate(ds, 6, alpha=0.3, seed=0)
        model = SplitModel(get_config("resnet8"))
        ecfg = EngineConfig(
            mode="s2fl", rounds=3, clients_per_round=4, batch_size=16,
            group_size=2,
            comm=CommConfig(latency=0.05, latency_dist="lognormal",
                            uplink_capacity=2e6, downlink_capacity=2e6),
            driver=DriverConfig(exec_mode="semi_async", staleness_cap=2,
                                quorum=0.5, pipeline=True,
                                server_concurrency=1,
                                gate_redispatch=True))
        return S2FLEngine(model, fed, ecfg)

    free = _make_engine(DriverConfig(exec_mode="semi_async",
                                     staleness_cap=2, quorum=0.5,
                                     pipeline=True), rounds=3)
    free.run(rounds=3)
    eng = build()
    eng.run(rounds=3)
    assert all(np.isfinite(h["loss"]) for h in eng.history)
    assert not eng._held                  # nothing dropped at shutdown
    assert eng.clock > 0
    replay = build()
    replay.run(rounds=3)
    assert replay.clock == eng.clock      # deterministic incl. draws
    assert replay.comm == eng.comm


@pytest.mark.slow
def test_engine_pipelined_semi_async_trains_for_real():
    from repro.configs import DriverConfig

    seq = _make_engine(DriverConfig(exec_mode="semi_async",
                                    staleness_cap=2, quorum=0.5))
    seq.run(rounds=4)
    pipe = _make_engine(DriverConfig(exec_mode="semi_async",
                                     staleness_cap=2, quorum=0.5,
                                     pipeline=True))
    pipe.run(rounds=4)
    # phase overlap can only help the clock further (static link)
    assert pipe.clock <= seq.clock + 1e-9
    assert not pipe._held                  # nothing dropped at shutdown
    assert all(np.isfinite(h["loss"]) for h in pipe.history)
    assert pipe.comm == pytest.approx(seq.comm)
    # per-direction byte accounting rides along in the history
    last = pipe.history[-1]
    assert last["comm_up"] > 0 and last["comm_down"] > 0
    assert last["comm_up"] + last["comm_down"] < last["comm"]
