"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.moe_gmm.kernel import moe_gmm
from repro.kernels.moe_gmm.ref import moe_gmm_ref
from repro.kernels.ssd_scan.kernel import ssd_scan_pallas
from repro.models.ssm import ssd_decode_step, ssd_scan_ref

KEY = jax.random.PRNGKey(7)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
FA_CASES = [
    # (BH, S, T, D, G, causal, window, dtype)
    (4, 128, 128, 64, 1, True, 0, jnp.float32),
    (4, 256, 256, 64, 2, True, 0, jnp.float32),
    (2, 256, 256, 128, 1, True, 64, jnp.float32),
    (6, 512, 512, 64, 3, False, 0, jnp.float32),
    (2, 128, 128, 32, 1, True, 0, jnp.bfloat16),
    (4, 384, 384, 64, 4, True, 128, jnp.float32),
    (2, 64, 64, 96, 2, True, 0, jnp.float32),
]


@pytest.mark.parametrize("BH,S,T,D,G,causal,window,dtype", FA_CASES)
def test_flash_attention_sweep(BH, S, T, D, G, causal, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (BH, S, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (BH // G, T, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (BH // G, T, D), jnp.float32).astype(dtype)
    out = flash_attention_fwd(q, k, v, causal=causal, window=window,
                              groups=G, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window, groups=G)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol)


def test_flash_attention_model_layout_matches_xla_path():
    from repro.models.attention import grouped_attention
    B, S, H, K, D = 2, 128, 8, 4, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, K, D))
    v = jax.random.normal(ks[2], (B, S, K, D))
    pos = jnp.arange(S, dtype=jnp.int32)
    ref = grouped_attention(q, k, v, pos, pos, causal=True, impl="xla")
    out = flash_attention(q, k, v, window=0, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------
SSD_CASES = [
    (2, 128, 4, 16, 8, 32, jnp.float32),
    (1, 256, 2, 64, 32, 64, jnp.float32),
    (2, 256, 3, 32, 16, 128, jnp.float32),
    (1, 128, 2, 32, 16, 32, jnp.bfloat16),
]


@pytest.mark.parametrize("b,s,h,p,n,chunk,dtype", SSD_CASES)
def test_ssd_scan_sweep(b, s, h, p, n, chunk, dtype):
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, s, n), jnp.float32).astype(dtype)
    C = jax.random.normal(ks[4], (b, s, n), jnp.float32).astype(dtype)
    init = (jax.random.normal(ks[5], (b, h, p, n), jnp.float32) * 0.1
            ).astype(dtype)
    y1, f1 = ssd_scan_pallas(x, dt, A, B, C, chunk=chunk,
                             initial_state=init, interpret=True)
    y2, f2 = ssd_scan_ref(x, dt, A, B, C, chunk=chunk, initial_state=init)
    # bf16 inputs quantize intermediate states; rtol dominates there
    atol, rtol = (0.1, 3e-2) if dtype == jnp.bfloat16 else (2e-4, 1e-5)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), atol=atol,
                               rtol=rtol)
    np.testing.assert_allclose(np.asarray(f1, np.float32),
                               np.asarray(f2, np.float32), atol=atol,
                               rtol=rtol)


def test_ssd_chunked_matches_sequential_recurrence():
    """The oracle itself vs step-by-step recurrence (ground truth)."""
    b, s, h, p, n = 2, 96, 4, 16, 8
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    y_chunk, fs = ssd_scan_ref(x, dt, A, B, C, chunk=32)
    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        y, state = ssd_decode_step(x[:, t:t + 1], dt[:, t:t + 1], A,
                                   B[:, t:t + 1], C[:, t:t + 1], state)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(y_chunk),
                               np.asarray(jnp.concatenate(ys, 1)), atol=3e-4)
    np.testing.assert_allclose(np.asarray(fs), np.asarray(state), atol=3e-4)


# ---------------------------------------------------------------------------
# moe gmm
# ---------------------------------------------------------------------------
GMM_CASES = [
    (4, 64, 128, 256, "silu", jnp.float32),
    (2, 128, 64, 512, "gelu", jnp.float32),
    (8, 32, 256, 128, "silu", jnp.float32),
    (2, 64, 128, 256, "silu", jnp.bfloat16),
    (3, 40, 96, 192, "gelu", jnp.float32),   # non-128 shapes
]


@pytest.mark.parametrize("E,C,d,F,act,dtype", GMM_CASES)
def test_moe_gmm_sweep(E, C, d, F, act, dtype):
    ks = jax.random.split(KEY, 4)
    x = (jax.random.normal(ks[0], (E, C, d)) * 0.5).astype(dtype)
    wg = (jax.random.normal(ks[1], (E, d, F)) * 0.05).astype(dtype)
    wu = (jax.random.normal(ks[2], (E, d, F)) * 0.05).astype(dtype)
    wd = (jax.random.normal(ks[3], (E, F, d)) * 0.05).astype(dtype)
    y1 = moe_gmm(x, wg, wu, wd, act=act, interpret=True)
    y2 = moe_gmm_ref(x, wg, wu, wd, act=act)
    atol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), atol=atol)
