"""Small pytree helpers used by Algorithm-1 aggregation and optimizers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def get_subtree(tree, path):
    node = tree
    for p in path:
        node = node[p]
    return node


def set_subtree(tree, path, value):
    """Functional set: returns a copy of `tree` with tree[path] = value."""
    if not path:
        return value
    head, rest = path[0], path[1:]
    if isinstance(tree, dict):
        out = dict(tree)
        out[head] = set_subtree(tree[head], rest, value)
        return out
    if isinstance(tree, (list, tuple)):
        out = list(tree)
        out[head] = set_subtree(tree[head], rest, value)
        return type(tree)(out) if isinstance(tree, tuple) else out
    raise TypeError(type(tree))


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_weighted_sum(trees, weights):
    """sum_i w_i * tree_i / sum_i w_i"""
    total = sum(weights)
    acc = tree_scale(trees[0], weights[0] / total)
    for t, w in zip(trees[1:], weights[1:]):
        acc = tree_add(acc, tree_scale(t, w / total))
    return acc


def tree_zeros_like(t):
    return jax.tree.map(jnp.zeros_like, t)
