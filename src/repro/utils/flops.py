"""Size / FLOPs accounting (the paper uses `thop`; this is the JAX
equivalent). Produces Figure-3 style per-portion sizes and FLOPs and the
Eq.-1 inputs (|Wc|, q, Fc, Fs) for the simulator.

Transformer costs are analytic (per sample of sequence length S);
CNN unit costs come from XLA's own cost model (``compiled.cost_analysis``
on a per-unit lowering), which is exact for convs.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.models.api import SplitModel, get_subtree
from repro.models.params import count_params


# ---------------------------------------------------------------------------
# parameter counts per segment
# ---------------------------------------------------------------------------
def segment_param_counts(model: SplitModel) -> dict:
    defs = model.defs()
    return {name: count_params(get_subtree(defs, path))
            for name, path in model.segments()}


def client_portion_size(model: SplitModel, split: int) -> float:
    counts = segment_param_counts(model)
    return float(sum(counts[n] for n in model.client_segments(split)))


def full_size(model: SplitModel) -> float:
    return float(sum(segment_param_counts(model).values()))


# ---------------------------------------------------------------------------
# forward FLOPs per unit, per sample
# ---------------------------------------------------------------------------
def _attn_fwd_flops(cfg, S: int) -> float:
    d, H = cfg.d_model, cfg.n_heads
    if cfg.mla:
        Dn, Dr, Dv, R = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                         cfg.v_head_dim, cfg.kv_lora_rank)
        proj = 2 * S * d * (H * (Dn + Dr) + R + Dr) \
            + 2 * S * R * H * (Dn + Dv) + 2 * S * H * Dv * d
        attn = 4 * S * S * H * (Dn + Dr) / 2            # causal half
        return proj + attn
    K, D = cfg.n_kv_heads, cfg.head_dim
    proj = 2 * S * d * D * (H + 2 * K) + 2 * S * H * D * d
    eff = min(S, cfg.sliding_window) if cfg.sliding_window else S
    attn = 4 * S * eff * H * D / (1 if cfg.sliding_window else 2)
    return proj + attn


def _mlp_fwd_flops(cfg, S: int, d_ff=None) -> float:
    ff = d_ff if d_ff is not None else cfg.d_ff
    return 6.0 * S * cfg.d_model * ff


def _moe_fwd_flops(cfg, S: int) -> float:
    routed = 6.0 * S * cfg.d_model * cfg.moe_d_ff * cfg.top_k
    shared = 6.0 * S * cfg.d_model * cfg.moe_d_ff * cfg.n_shared_experts
    router = 2.0 * S * cfg.d_model * cfg.n_experts
    return routed + shared + router


def _ssm_fwd_flops(cfg, S: int) -> float:
    d, di, N, Hs, P = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                       cfg.n_ssm_heads, cfg.ssm_head_dim)
    proj = 2 * S * d * (2 * di + 2 * N + Hs) + 2 * S * di * d
    conv = 2 * S * cfg.ssm_conv * (di + 2 * N)
    l = cfg.ssm_chunk
    # per chunk: CB^T (l²N) + W·x (l²·Hs·P) + state in/out (2·l·Hs·P·N)
    chunks = S / l
    ssd = chunks * (2 * l * l * N + 2 * l * l * Hs * P
                    + 4 * l * Hs * P * N)
    return proj + conv + ssd


def transformer_unit_flops(cfg, S: int) -> list:
    """Per-block fwd FLOPs for one sample of length S."""
    out = []
    for mixer, ffn in cfg.pattern():
        f = 0.0
        if mixer == "ssm":
            f += _ssm_fwd_flops(cfg, S)
        else:
            import dataclasses as _dc
            # 'attn' layers are global even when cfg carries a window
            # (gemma3's 5 local : 1 global pattern)
            c = cfg if mixer == "swa" else _dc.replace(cfg, sliding_window=0)
            f += _attn_fwd_flops(c, S)
        if ffn == "dense":
            f += _mlp_fwd_flops(cfg, S)
        elif ffn == "moe":
            f += _moe_fwd_flops(cfg, S)
        out.append(f)
    return out


def head_flops(cfg, S: int) -> float:
    return 2.0 * S * cfg.d_model * cfg.vocab_padded


@functools.lru_cache(maxsize=64)
def _cnn_unit_costs(cfg) -> tuple:
    """(fwd_flops, out_feature_elems) per unit via XLA cost analysis."""
    from repro.models.cnn import cnn_units
    units, _ = cnn_units(cfg)
    model = SplitModel(cfg)
    params_abs = model.abstract()
    x = jax.ShapeDtypeStruct((1, cfg.image_size, cfg.image_size,
                              cfg.in_channels), jnp.float32)
    out = []
    for i, (defs_i, apply_i) in enumerate(units):
        f = jax.jit(apply_i)
        lowered = f.lower(jax.tree.map(
            lambda d: jax.ShapeDtypeStruct(d.shape, jnp.float32),
            params_abs["units"][i]), x)
        cost = lowered.compile().cost_analysis()
        if isinstance(cost, (list, tuple)):     # older jaxlib: per-device list
            cost = cost[0] if cost else {}
        flops = float(cost.get("flops", 0.0))
        x = jax.eval_shape(apply_i, jax.tree.map(
            lambda d: jax.ShapeDtypeStruct(d.shape, jnp.float32),
            params_abs["units"][i]), x)
        out.append((flops, float(math.prod(x.shape[1:]))))
    return tuple(out)


# ---------------------------------------------------------------------------
# Eq.-1 inputs for a given split
# ---------------------------------------------------------------------------
def split_costs(model: SplitModel, split: int, *, seq_len: int = 0) -> dict:
    """Per-sample Eq.-1 quantities for split s:
    wc_size (elements), feat_size q (elements/sample),
    fc / fs (fwd+bwd FLOPs per sample, bwd = 2x fwd)."""
    cfg = model.cfg
    counts = segment_param_counts(model)
    wc = client_portion_size(model, split)
    if model.is_cnn:
        unit_costs = _cnn_unit_costs(cfg)
        fwd = [f for f, _ in unit_costs]
        feat = unit_costs[split - 1][1] if split >= 1 else float(
            cfg.image_size ** 2 * cfg.in_channels)
        head = 2.0 * unit_costs[-1][1]
    else:
        S = seq_len + (cfg.n_frontend_tokens if cfg.frontend else 0)
        fwd = transformer_unit_flops(cfg, S)
        feat = float(S * cfg.d_model)
        head = head_flops(cfg, S)
    fc = 3.0 * sum(fwd[:split])
    fs = 3.0 * (sum(fwd[split:]) + head)
    return {"wc_size": wc, "feat_size": feat, "fc": fc, "fs": fs,
            "w_size": float(sum(counts.values())),
            "f_full": 3.0 * (sum(fwd) + head)}


def model_flops_6nd(cfg, n_tokens: int) -> float:
    """MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) for the roofline
    useful-compute ratio."""
    model = SplitModel(cfg)
    counts = segment_param_counts(model)
    total = sum(counts.values())
    if cfg.n_experts:
        # active = total - routed expert params + top_k/E * routed
        routed = 0
        for name, path in model.segments():
            if not name.startswith("block:"):
                continue
            i = int(name.split(":")[1])
            if cfg.pattern()[i][1] == "moe":
                E, F, d = cfg.n_experts, cfg.moe_d_ff, cfg.d_model
                routed += 3 * E * d * F
        active = total - routed + routed * cfg.top_k / cfg.n_experts
        return 6.0 * active * n_tokens
    return 6.0 * total * n_tokens
