"""Post-SPMD HLO analysis: collective-bytes accounting + roofline terms.

``compiled.as_text()`` (optimized HLO, after the SPMD partitioner) contains
the actual collective ops; we sum the output-buffer bytes of every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute
as the per-chip collective traffic proxy (operand ~= output size for these
ops up to the reduce/gather factor).

Hardware constants: TPU v5e-class per the brief —
197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12          # per chip, bf16
HBM_BW = 819e9               # per chip, bytes/s
ICI_BW = 50e9                # per link, bytes/s

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_LINE_RE = re.compile(
    r"=\s+(?P<ty>\([^=]*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(?P<op>" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(")
_TYPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _type_bytes(ty: str) -> int:
    total = 0
    for dt, dims in _TYPE_RE.findall(ty):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-op-kind output bytes summed over the module (per-chip view —
    SPMD HLO is the single-device program)."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for m in _LINE_RE.finditer(hlo_text):
        op = m.group("op")
        # '-done' duplicates '-start' buffers; count once (start only)
        span = hlo_text[m.start():m.end()]
        if "-done(" in span:
            continue
        out[op] += _type_bytes(m.group("ty"))
        counts[op] += 1
    out["_counts"] = counts
    out["_total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    """cost_analysis() on an SPMD-partitioned module reports the PER-CHIP
    program (verified empirically: a (1024³) matmul sharded 4-way reports
    flops/4), so hlo_flops / hlo_bytes / coll_bytes here are all per-chip;
    the brief's 'HLO_FLOPs / (chips × peak)' is equivalent with global
    flops = per-chip × chips."""
    arch: str
    shape: str
    n_chips: int
    hlo_flops: float             # per-chip
    hlo_bytes: float             # per-chip
    coll_bytes: float            # per-chip collective traffic
    model_flops: float           # global 6·N·D useful compute
    coll_detail: dict = dataclasses.field(default_factory=dict)

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        total = self.hlo_flops * self.n_chips
        return self.model_flops / total if total else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "chips": self.n_chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "dominant": self.dominant,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes, "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
        }


def analyze(compiled, *, arch: str, shape: str, n_chips: int,
            model_flops: float) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    return Roofline(arch=arch, shape=shape, n_chips=n_chips,
                    hlo_flops=flops, hlo_bytes=byts,
                    coll_bytes=float(coll["_total"]),
                    model_flops=model_flops, coll_detail=coll)
