"""Synthetic datasets (offline container — no CIFAR/ImageNet/FEMNIST
downloads). Two families:

- image classification: Gaussian class prototypes (smooth random patterns)
  + per-sample noise at CIFAR shapes; learnable, non-trivial, and class
  structure supports the paper's Dirichlet non-IID protocol.
- token LM: per-domain bigram chains over disjoint-ish token ranges; the
  'domain' plays the role of the label for the data-balance mechanism.
"""
from __future__ import annotations

import numpy as np


def make_image_dataset(n: int, *, n_classes: int = 10, image_size: int = 32,
                       channels: int = 3, noise: float = 0.6,
                       seed: int = 0, proto_seed: int = 0):
    """Returns {'x': (n,H,W,C) f32, 'y': (n,) i32}.

    ``proto_seed`` fixes the class prototypes INDEPENDENTLY of the sample
    seed, so train/test splits drawn with different ``seed`` share the
    same classification task (they must — an earlier version regenerated
    prototypes per split, making test accuracy random; see EXPERIMENTS).
    """
    rng = np.random.default_rng(seed)
    # smooth prototypes: low-frequency random fields per class
    freq = 4
    base = np.random.default_rng(proto_seed).normal(
        size=(n_classes, freq, freq, channels))
    protos = np.stack([
        np.kron(base[c], np.ones((image_size // freq, image_size // freq, 1)))
        for c in range(n_classes)])
    protos = protos / np.abs(protos).max()
    y = rng.integers(0, n_classes, size=n)
    x = protos[y] + noise * rng.normal(size=(n, image_size, image_size,
                                             channels))
    return {"x": x.astype(np.float32), "y": y.astype(np.int32)}


def make_lm_dataset(n: int, *, seq_len: int = 64, vocab: int = 256,
                    n_domains: int = 10, seed: int = 0):
    """Per-domain bigram chains: domain d prefers the token band
    [d*vocab/n, (d+1)*vocab/n) with a deterministic +step drift, so
    next-token prediction is learnable and domain-distinguishable.

    Returns {'tokens': (n,S) i32, 'labels': (n,S) i32 (shifted),
             'y': (n,) i32 domain ids}."""
    rng = np.random.default_rng(seed)
    band = max(vocab // n_domains, 4)
    y = rng.integers(0, n_domains, size=n)
    toks = np.zeros((n, seq_len + 1), np.int32)
    for i in range(n):
        lo = (y[i] * band) % max(vocab - band, 1)
        t = lo + rng.integers(0, band)
        step = 1 + (y[i] % 3)
        seq = [t]
        for _ in range(seq_len):
            if rng.random() < 0.15:                      # noise token
                seq.append(int(lo + rng.integers(0, band)))
            else:
                seq.append(int(lo + (seq[-1] - lo + step) % band))
        toks[i] = seq[:seq_len + 1]
    return {"tokens": toks[:, :-1],
            "labels": toks[:, 1:].astype(np.int32),
            "y": y.astype(np.int32)}
