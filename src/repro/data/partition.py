"""Non-IID federated partitioning — Dirichlet(α) over label proportions
(the paper's protocol for CIFAR/ImageNet, §5.1) plus IID and
shards-per-client alternatives.
"""
from __future__ import annotations

import numpy as np


def dirichlet_partition(labels, n_clients: int, alpha: float,
                        seed: int = 0, min_per_client: int = 2):
    """Returns list of index arrays, one per client. Classic protocol:
    for each class, split its sample indices by Dirichlet(alpha)
    proportions across clients."""
    rng = np.random.default_rng(seed)
    labels = np.asarray(labels)
    n_classes = int(labels.max()) + 1
    client_idx = [[] for _ in range(n_clients)]
    for c in range(n_classes):
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * n_clients)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for cid, part in enumerate(np.split(idx, cuts)):
            client_idx[cid].extend(part.tolist())
    # guarantee a minimum (move from the largest client)
    for cid in range(n_clients):
        while len(client_idx[cid]) < min_per_client:
            donor = int(np.argmax([len(ci) for ci in client_idx]))
            client_idx[cid].append(client_idx[donor].pop())
    return [np.asarray(sorted(ci), dtype=np.int64) for ci in client_idx]


def iid_partition(n: int, n_clients: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n)
    return [np.sort(p) for p in np.array_split(idx, n_clients)]


def federate(dataset: dict, n_clients: int, *, alpha=None, seed: int = 0):
    """Split a dataset dict into {cid: dataset dict}. alpha=None -> IID."""
    labels = dataset["y"]
    if alpha is None:
        parts = iid_partition(len(labels), n_clients, seed)
    else:
        parts = dirichlet_partition(labels, n_clients, alpha, seed)
    return {cid: {k: v[p] for k, v in dataset.items()}
            for cid, p in enumerate(parts)}
