"""Batched cohort compression: one jitted call per direction.

The sequential channel path encodes each (device, tensor) transfer as
its own dispatch chain — residual add, select/quantize, decode, residual
update, one python round-trip per device. When the engine flushes a
cohort together (all participants' uplinks, then all downlinks), the
per-device tensors share a shape, so the whole direction collapses to a
single (D, N) stacked buffer and ONE jitted, donated call into
``repro.kernels.comm_fused`` (Pallas kernels or their jnp oracles,
selected by the same REPRO_COMM_KERNEL backend logic as the sequential
int8 path).

Compatibility contract with the sequential path (tested in
tests/test_fused_comm.py):

* wire bytes are BIT-equal — computed analytically here from the same
  integer geometry the sequential codecs meter (sparse: k*(4+4)+4;
  int8: R*g + 8R via ``int8_group_geometry``; casts: n * width), so
  per-device meters, Eq.-1 clocks and recorder counters are identical;
* delivered tensors and residuals match to ≤1e-6 (same math, but one
  fused XLA program may contract multiply-adds differently than the
  per-device chain);
* the error-feedback residual dict is mutated with the sequential
  semantics exactly: residual added only when its shape matches, the
  new residual ``(x + r) - decode(encode(x + r))`` always stored, fp32
  short-circuited (its residual is identically zero);
* rand-k index draws happen host-side through the codec's own
  ``draw_indices`` counter stream, one draw per tensor in sequential
  transfer order, so the survivor masks (and any later sequential
  replay) are identical.

Items whose shapes differ still batch: the cohort is bucketed by
(shape, dtype) and each bucket is one fused call.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.comm.codecs import INDEX_BYTES, SPARSE_HEADER_BYTES
from repro.kernels.comm_fused import (fused_cast_roundtrip,
                                      fused_int8_roundtrip,
                                      fused_sparse_roundtrip,
                                      int8_group_geometry)

SUPPORTED = ("fp32", "bf16", "fp16", "int8", "topk", "randk")


def supports(codec) -> bool:
    """True when this codec has a fused cohort implementation; the
    channel falls back to the sequential per-tensor path otherwise."""
    return getattr(codec, "name", "") in SUPPORTED


def payload_bytes(codec, n: int) -> float:
    """Exact wire bytes for one n-element tensor under ``codec`` —
    the same integer arithmetic the sequential encode meters from the
    materialized payload, so the two paths' byte counters are
    bit-equal (every term is an exact small integer in float64)."""
    name = codec.name
    if name in ("fp32", "bf16", "fp16"):
        return float(n) * codec.bytes_per_value
    if name == "int8":
        g, rows = int8_group_geometry(n)
        return float(rows * g) * codec.bytes_per_value \
            + float(rows) * codec.row_overhead_bytes
    # sparsifiers: (index, value) pair per survivor + count header
    k = codec._k(n)
    return k * (codec.value_bytes + INDEX_BYTES) + SPARSE_HEADER_BYTES


def cohort_roundtrip(codec, items, residuals: dict, error_feedback: bool):
    """Run a whole cohort's transfers through the fused kernels.

    ``items``: [(residual_key, tensor)] in the EXACT order the
    sequential path would have transferred them — rand-k draws and
    residual mutations depend on it. Returns [(delivered, wire_bytes)]
    aligned with ``items``; ``residuals`` is mutated in place with
    sequential-identical keying/overwrite/shape-reset semantics.
    """
    name = codec.name
    ef = bool(error_feedback) and name != "fp32"

    # host-side rand-k draws FIRST, in sequential transfer order, so the
    # codec's per-call counter stream stays replay-identical no matter
    # how the bucketing below regroups the tensors
    draws = [None] * len(items)
    if name == "randk":
        for i, (_, x) in enumerate(items):
            n = int(np.prod(x.shape)) if x.shape else 1
            draws[i] = np.asarray(codec.draw_indices(n, codec._k(n)))

    buckets = {}                      # (shape, dtype) -> item indices
    for i, (_, x) in enumerate(items):
        buckets.setdefault((tuple(x.shape), str(x.dtype)), []).append(i)

    out = [None] * len(items)
    for (shape, _), idxs in buckets.items():
        xs = jnp.stack([jnp.ravel(items[i][1]) for i in idxs])
        n = xs.shape[1]
        r_stack = None
        if ef:
            rows = []
            for i in idxs:
                r = residuals.get(items[i][0])
                # sequential shape-reset rule: a stale-shaped residual
                # is ignored (adding zero is exact, so missing rows ride
                # the same fused call as held ones)
                if r is not None and tuple(r.shape) == shape:
                    rows.append(jnp.ravel(r).astype(xs.dtype))
                else:
                    rows.append(jnp.zeros((n,), xs.dtype))
            r_stack = jnp.stack(rows)

        if name == "fp32":
            delivered, new_r = xs, None
        elif name in ("bf16", "fp16"):
            delivered, new_r = fused_cast_roundtrip(
                xs, r_stack, wire_dtype=codec.wire_dtype)
        elif name == "int8":
            delivered, new_r = fused_int8_roundtrip(xs, r_stack)
        else:
            k = codec._k(n)
            delivered, new_r = fused_sparse_roundtrip(
                xs, r_stack, k=k, scale=codec._scale(k, n),
                indices=(np.stack([draws[i] for i in idxs])
                         if name == "randk" else None))

        nbytes = payload_bytes(codec, n)
        for j, i in enumerate(idxs):
            if ef:
                residuals[items[i][0]] = new_r[j].reshape(shape)
            out[i] = (delivered[j].reshape(shape), nbytes)
    return out
