"""Payload codecs for the cut-layer exchange (uplink features, downlink
feature-gradients).

A codec is a wire format: ``encode`` produces the payload that would
cross the link (plus exact wire bytes), ``decode`` reconstructs the
tensor the receiver trains on. The engine always trains on
``decode(encode(x))`` so codec round-trip error is injected into the
training path — compression is never free by construction.

Byte accounting is exact per payload (see comm/README.md): element
payload bytes + per-row metadata (int8: fp32 scale+zp per row) + a fixed
4-byte aux scalar carried alongside each feature tensor. Sparsifiers
(top-k / random-k) ship an index+value pair per surviving entry plus a
4-byte count header per tensor.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.int8_quant import int8_dequantize, int8_quantize


class Codec:
    """Wire format for a single tensor. Subclasses set ``name`` and
    ``bytes_per_value`` and implement encode/decode."""

    name: str = "base"
    bytes_per_value: float = 4.0
    row_overhead_bytes: float = 0.0     # per-row metadata (scales etc.)

    def encode(self, x):
        """-> (payload, wire_bytes). payload is whatever decode needs."""
        raise NotImplementedError

    def decode(self, payload, dtype=jnp.float32):
        raise NotImplementedError

    def roundtrip(self, x):
        """The tensor the receiver sees, plus exact wire bytes."""
        payload, nbytes = self.encode(x)
        return self.decode(payload, dtype=x.dtype), nbytes

    def estimate_bytes(self, n_values: float, last_dim: int = 0) -> float:
        """Analytic wire size for n_values elements (used by the Eq.-1
        simulator for devices whose payloads are not materialized, e.g.
        warm-up observation of non-participants)."""
        rows = n_values / last_dim if last_dim else 1.0
        return n_values * self.bytes_per_value \
            + math.ceil(rows) * self.row_overhead_bytes


class Fp32Codec(Codec):
    name = "fp32"
    bytes_per_value = 4.0

    def encode(self, x):
        return x, float(x.size) * self.bytes_per_value

    def decode(self, payload, dtype=jnp.float32):
        return payload.astype(dtype)


class CastCodec(Codec):
    """Lossy downcast (bf16 / fp16): halves the wire size."""
    bytes_per_value = 2.0

    def __init__(self, name: str, wire_dtype):
        self.name = name
        self.wire_dtype = wire_dtype

    def encode(self, x):
        return x.astype(self.wire_dtype), \
            float(x.size) * self.bytes_per_value

    def decode(self, payload, dtype=jnp.float32):
        return payload.astype(dtype)


class Int8Codec(Codec):
    """Group-wise affine int8 via the Pallas kernel pair
    (repro.kernels.int8_quant): 1 byte/value + 8 bytes per group of
    QUANT_GROUP values (fp32 scale + zero point), ~3% metadata."""
    name = "int8"
    bytes_per_value = 1.0
    row_overhead_bytes = 8.0

    def encode(self, x):
        q, scale, zp, shape = int8_quantize(x)
        # the edge-padded tail group crosses the wire too — count it
        nbytes = float(q.size) * self.bytes_per_value \
            + float(q.shape[0]) * self.row_overhead_bytes
        return (q, scale, zp, shape), nbytes

    def decode(self, payload, dtype=jnp.float32):
        q, scale, zp, shape = payload
        return int8_dequantize(q, scale, zp, shape, dtype=dtype)

    def estimate_bytes(self, n_values: float, last_dim: int = 0) -> float:
        from repro.kernels.int8_quant.ops import GROUP
        if not n_values:
            return 0.0
        # mirror _as_groups: tensors smaller than GROUP use one
        # tensor-sized group, not a full padded one
        g = min(GROUP, int(n_values))
        groups = math.ceil(n_values / g)
        return groups * (g * self.bytes_per_value
                         + self.row_overhead_bytes)


# ---------------------------------------------------------------------------
# sparsification (index+value wire format)
# ---------------------------------------------------------------------------
DEFAULT_TOPK_FRAC = 0.1
INDEX_BYTES = 4.0            # int32 flat index per surviving entry
SPARSE_HEADER_BYTES = 4.0    # entry-count header per tensor


class SparseCodec(Codec):
    """Send only ``k = ceil(frac * size)`` entries of the flattened
    tensor: each survivor crosses the wire as (int32 flat index, fp32
    value) — 8 B/entry — plus a 4-byte count header per tensor. The
    receiver scatters into zeros, so the round-trip error is exactly the
    dropped mass; pair with the channel's error-feedback accumulators to
    re-inject it next round instead of losing it."""

    value_bytes = 4.0

    def __init__(self, name: str, frac: float = DEFAULT_TOPK_FRAC):
        if not 0.0 < frac <= 1.0:
            raise ValueError(f"topk_frac must be in (0, 1]: {frac}")
        self.name = name
        self.frac = float(frac)
        self.bytes_per_value = self.frac * (self.value_bytes + INDEX_BYTES)

    def _k(self, n: int) -> int:
        return max(1, math.ceil(self.frac * n))

    def _select(self, flat, k: int):
        raise NotImplementedError

    def _scale(self, k: int, n: int) -> float:
        return 1.0

    def encode(self, x):
        flat = x.reshape(-1).astype(jnp.float32)
        k = self._k(flat.size)
        idx = self._select(flat, k)
        vals = flat[idx] * self._scale(k, flat.size)
        nbytes = k * (self.value_bytes + INDEX_BYTES) + SPARSE_HEADER_BYTES
        return (idx, vals, x.shape), nbytes

    def decode(self, payload, dtype=jnp.float32):
        idx, vals, shape = payload
        out = jnp.zeros(math.prod(shape), jnp.float32).at[idx].set(vals)
        return out.reshape(shape).astype(dtype)

    def estimate_bytes(self, n_values: float, last_dim: int = 0) -> float:
        if not n_values:
            return 0.0
        return self._k(int(n_values)) * (self.value_bytes + INDEX_BYTES) \
            + SPARSE_HEADER_BYTES


class TopKCodec(SparseCodec):
    """Keep the k largest-magnitude entries (biased; the standard
    error-feedback partner)."""

    def __init__(self, frac: float = DEFAULT_TOPK_FRAC):
        super().__init__("topk", frac)

    def _select(self, flat, k):
        return jax.lax.top_k(jnp.abs(flat), k)[1]


class RandomKCodec(SparseCodec):
    """Keep k uniformly random entries, scaled by n/k so the estimator
    is unbiased (QSGD-style). Index draws come from a deterministic
    per-call counter seed, so runs are reproducible without threading
    RNG state through the channel.

    ``unbiased=False`` drops the n/k scaling: the scaled operator is
    not a contraction (||x - C(x)|| can exceed ||x||), which makes
    error-feedback accumulators diverge — the channel flips this flag
    when feedback is on, since re-injecting the residual already
    compensates the bias."""

    def __init__(self, frac: float = DEFAULT_TOPK_FRAC, seed: int = 0,
                 unbiased: bool = True):
        super().__init__("randk", frac)
        self.seed = seed
        self.unbiased = unbiased
        self._calls = 0

    def draw_indices(self, n: int, k: int):
        """Advance the per-call counter and draw this call's survivor
        indices (host-side numpy). Exposed so the batched cohort path
        can consume the SAME counter stream in the same order as the
        sequential per-tensor path — one draw per tensor either way, so
        a run's index masks are identical whichever path carried it."""
        self._calls += 1
        rng = np.random.default_rng((self.seed, self._calls))
        return rng.choice(n, size=k, replace=False)

    def _select(self, flat, k):
        return jnp.asarray(self.draw_indices(flat.size, k))

    def _scale(self, k, n):
        return n / k if self.unbiased else 1.0

    # ------------------------------------------------- replayable state
    def state(self) -> dict:
        """Checkpointable RNG-stream position: restoring (seed, calls)
        and replaying makes every subsequent index draw identical."""
        return {"seed": self.seed, "calls": self._calls}

    def set_state(self, state: dict):
        self.seed = state["seed"]
        self._calls = int(state["calls"])

    def reset(self):
        """Rewind the call counter to the start of the stream (a fresh
        run from the same seed)."""
        self._calls = 0


_CODECS = {
    "fp32": Fp32Codec,
    "bf16": lambda: CastCodec("bf16", jnp.bfloat16),
    "fp16": lambda: CastCodec("fp16", jnp.float16),
    "int8": Int8Codec,
    "topk": TopKCodec,
    "randk": RandomKCodec,
}

_SPARSE = ("topk", "randk")


def get_codec(name: str, *, topk_frac: float = None) -> Codec:
    if name not in _CODECS:
        raise ValueError(
            f"unknown codec {name!r}; known codecs: {list_codecs()}")
    if name in _SPARSE and topk_frac is not None:
        return _CODECS[name](topk_frac)
    return _CODECS[name]()


def list_codecs():
    return sorted(_CODECS)
