"""Payload codecs for the cut-layer exchange (uplink features, downlink
feature-gradients).

A codec is a wire format: ``encode`` produces the payload that would
cross the link (plus exact wire bytes), ``decode`` reconstructs the
tensor the receiver trains on. The engine always trains on
``decode(encode(x))`` so codec round-trip error is injected into the
training path — compression is never free by construction.

Byte accounting is exact per payload (see comm/README.md): element
payload bytes + per-row metadata (int8: fp32 scale+zp per row) + a fixed
4-byte aux scalar carried alongside each feature tensor.
"""
from __future__ import annotations

import math

import jax.numpy as jnp

from repro.kernels.int8_quant import int8_dequantize, int8_quantize


class Codec:
    """Wire format for a single tensor. Subclasses set ``name`` and
    ``bytes_per_value`` and implement encode/decode."""

    name: str = "base"
    bytes_per_value: float = 4.0
    row_overhead_bytes: float = 0.0     # per-row metadata (scales etc.)

    def encode(self, x):
        """-> (payload, wire_bytes). payload is whatever decode needs."""
        raise NotImplementedError

    def decode(self, payload, dtype=jnp.float32):
        raise NotImplementedError

    def roundtrip(self, x):
        """The tensor the receiver sees, plus exact wire bytes."""
        payload, nbytes = self.encode(x)
        return self.decode(payload, dtype=x.dtype), nbytes

    def estimate_bytes(self, n_values: float, last_dim: int = 0) -> float:
        """Analytic wire size for n_values elements (used by the Eq.-1
        simulator for devices whose payloads are not materialized, e.g.
        warm-up observation of non-participants)."""
        rows = n_values / last_dim if last_dim else 1.0
        return n_values * self.bytes_per_value \
            + math.ceil(rows) * self.row_overhead_bytes


class Fp32Codec(Codec):
    name = "fp32"
    bytes_per_value = 4.0

    def encode(self, x):
        return x, float(x.size) * self.bytes_per_value

    def decode(self, payload, dtype=jnp.float32):
        return payload.astype(dtype)


class CastCodec(Codec):
    """Lossy downcast (bf16 / fp16): halves the wire size."""
    bytes_per_value = 2.0

    def __init__(self, name: str, wire_dtype):
        self.name = name
        self.wire_dtype = wire_dtype

    def encode(self, x):
        return x.astype(self.wire_dtype), \
            float(x.size) * self.bytes_per_value

    def decode(self, payload, dtype=jnp.float32):
        return payload.astype(dtype)


class Int8Codec(Codec):
    """Group-wise affine int8 via the Pallas kernel pair
    (repro.kernels.int8_quant): 1 byte/value + 8 bytes per group of
    QUANT_GROUP values (fp32 scale + zero point), ~3% metadata."""
    name = "int8"
    bytes_per_value = 1.0
    row_overhead_bytes = 8.0

    def encode(self, x):
        q, scale, zp, shape = int8_quantize(x)
        # the edge-padded tail group crosses the wire too — count it
        nbytes = float(q.size) * self.bytes_per_value \
            + float(q.shape[0]) * self.row_overhead_bytes
        return (q, scale, zp, shape), nbytes

    def decode(self, payload, dtype=jnp.float32):
        q, scale, zp, shape = payload
        return int8_dequantize(q, scale, zp, shape, dtype=dtype)

    def estimate_bytes(self, n_values: float, last_dim: int = 0) -> float:
        from repro.kernels.int8_quant.ops import GROUP
        if not n_values:
            return 0.0
        # mirror _as_groups: tensors smaller than GROUP use one
        # tensor-sized group, not a full padded one
        g = min(GROUP, int(n_values))
        groups = math.ceil(n_values / g)
        return groups * (g * self.bytes_per_value
                         + self.row_overhead_bytes)


_CODECS = {
    "fp32": Fp32Codec,
    "bf16": lambda: CastCodec("bf16", jnp.bfloat16),
    "fp16": lambda: CastCodec("fp16", jnp.float16),
    "int8": Int8Codec,
}


def get_codec(name: str) -> Codec:
    if name not in _CODECS:
        raise KeyError(f"unknown codec {name!r}; known: {sorted(_CODECS)}")
    return _CODECS[name]()


def list_codecs():
    return sorted(_CODECS)
