"""repro.comm — pluggable transport for the cut-layer exchange.

See README.md in this package for the design (codec interface,
link-trace format, byte-accounting convention)."""
from repro.comm.channel import (AUX_BYTES, MESSAGES_PER_ROUND,  # noqa: F401
                                CommChannel)
from repro.comm.codecs import Codec, get_codec, list_codecs  # noqa: F401
from repro.comm.links import (FluidLink, LatencySampler,  # noqa: F401
                              LinkTrace, StaticLink, fluid_schedule,
                              get_link, shared_link_finish_times)


def make_channel(ccfg=None) -> CommChannel:
    """Build a CommChannel from a configs.base.CommConfig (None -> the
    fp32/static default, which reproduces the seed's exact semantics)."""
    if ccfg is None:
        return CommChannel()
    if ccfg.link == "trace":
        if ccfg.trace_file:
            link = LinkTrace.from_file(
                ccfg.trace_file,
                per_device_phase=ccfg.trace_phase_per_device)
        else:
            link = LinkTrace(ccfg.trace_times, ccfg.trace_multipliers,
                             period=ccfg.trace_period,
                             per_device_phase=ccfg.trace_phase_per_device)
    else:
        link = get_link(ccfg.link)
    # the *_codec fields are the preferred names; codec/grad_codec are
    # the original storage fields they override when set
    codec = getattr(ccfg, "uplink_codec", "") or ccfg.codec
    grad = getattr(ccfg, "downlink_codec", "") or ccfg.grad_codec
    return CommChannel(codec=codec, grad_codec=grad, link=link,
                       dispatch_codec=getattr(ccfg, "dispatch_codec",
                                              "fp32"),
                       error_feedback=getattr(ccfg, "error_feedback",
                                              False),
                       topk_frac=getattr(ccfg, "topk_frac", None),
                       latency=getattr(ccfg, "latency", 0.0),
                       uplink_capacity=getattr(ccfg, "uplink_capacity",
                                               0.0),
                       downlink_capacity=getattr(ccfg,
                                                 "downlink_capacity", 0.0),
                       latency_dist=getattr(ccfg, "latency_dist",
                                            "constant"),
                       latency_jitter=getattr(ccfg, "latency_jitter",
                                              0.5),
                       latency_seed=getattr(ccfg, "latency_seed", 0))
