"""CommChannel — the metered transport between devices and the Main
Server.

Everything that crosses the cut goes through here: uplink features
(step 4 of Fig. 1) and downlink feature-gradients (step 7). The channel
(a) applies the codec round-trip so the receiver trains on exactly what
the wire delivered, and (b) meters exact payload bytes per direction and
per device-round, which the engine's Eq.-1 tick converts to transfer
time using the link model's rate at the current simulated clock.

Byte convention (comm/README.md): payload bytes are exact from the
encoded arrays; model dispatch/collection is fp32, i.e.
``elements * BYTES_PER_ELEM`` — codecs apply to the cut-layer exchange
only, matching the paper's Eq.-1 structure.

Two transport-delay knobs ride on the channel (both default off, so the
fp32/static seed regime is untouched):

``latency``          per-message seconds. A device-round exchanges four
                     messages (Wc dispatch, features up, gradients down,
                     Wc collect), so the atomic Eq.-1 time gains
                     ``4 * latency``; the phase pipeline charges two
                     latencies to the upload phase and two to the
                     download phase.
``uplink_capacity``  the Main Server's shared ingress in Table-1
                     elements/s (0 = uncontended). Only the phase-level
                     pipeline can observe overlap, so contention prices
                     only pipelined timelines — see
                     ``links.shared_link_finish_times``.
"""
from __future__ import annotations

from repro.comm.codecs import Codec, get_codec
from repro.comm.links import StaticLink

AUX_BYTES = 4.0          # the scalar aux-loss rider on each feature msg
MESSAGES_PER_ROUND = 4   # dispatch, features up, grads down, collect


class CommChannel:
    def __init__(self, codec="fp32", grad_codec=None, link=None, *,
                 latency: float = 0.0, uplink_capacity: float = 0.0):
        self.feature_codec = (codec if isinstance(codec, Codec)
                              else get_codec(codec))
        if grad_codec is None or grad_codec == "":
            grad_codec = self.feature_codec.name
        self.grad_codec = (grad_codec if isinstance(grad_codec, Codec)
                           else get_codec(grad_codec))
        self.link = link or StaticLink()
        if latency < 0:
            raise ValueError(f"latency must be >= 0: {latency}")
        if uplink_capacity < 0:
            raise ValueError(
                f"uplink_capacity must be >= 0 (0 = uncontended): "
                f"{uplink_capacity}")
        self.latency = float(latency)
        self.uplink_capacity = float(uplink_capacity)
        self.up_bytes = 0.0          # device -> server (features)
        self.down_bytes = 0.0        # server -> device (dfx)
        self._round_up = {}          # cid -> uplink payload bytes this round
        self._round_down = {}        # cid -> downlink payload bytes

    # ------------------------------------------------------------ wire
    def _xfer(self, codec, cid, msg, meter):
        """msg: {'h': tensor, ...riders} or bare tensor."""
        if isinstance(msg, dict):
            h, nbytes = codec.roundtrip(msg["h"])
            out = dict(msg, h=h)
            nbytes += AUX_BYTES * (len(msg) - 1)
        else:
            out, nbytes = codec.roundtrip(msg)
        meter[cid] = meter.get(cid, 0.0) + nbytes
        return out, nbytes

    def uplink_features(self, cid, feats):
        """Device cid uploads its cut-layer features. Returns what the
        server receives (codec round-trip applied)."""
        out, nbytes = self._xfer(self.feature_codec, cid, feats,
                                 self._round_up)
        self.up_bytes += nbytes
        return out

    def downlink_grads(self, cid, dfx):
        """Server returns the feature gradient to device cid."""
        out, nbytes = self._xfer(self.grad_codec, cid, dfx,
                                 self._round_down)
        self.down_bytes += nbytes
        return out

    # ------------------------------------------------------- accounting
    @property
    def total_bytes(self) -> float:
        return self.up_bytes + self.down_bytes

    def round_payload(self, cid) -> float:
        """Exact payload bytes metered for cid since the last reset."""
        return self._round_up.get(cid, 0.0) \
            + self._round_down.get(cid, 0.0)

    def round_payload_split(self, cid):
        """(uplink, downlink) payload bytes metered for cid this round —
        the per-direction split the phase pipeline prices."""
        return (self._round_up.get(cid, 0.0),
                self._round_down.get(cid, 0.0))

    def reset_round(self):
        self._round_up = {}
        self._round_down = {}

    def estimate_uplink_payload(self, n_values: float,
                                last_dim: int = 0) -> float:
        """Analytic uplink (feature) payload bytes for n_values cut-layer
        elements — the upload phase's wire traffic."""
        return self.feature_codec.estimate_bytes(n_values, last_dim) \
            + AUX_BYTES

    def estimate_downlink_payload(self, n_values: float,
                                  last_dim: int = 0) -> float:
        """Analytic downlink (feature-gradient) payload bytes."""
        return self.grad_codec.estimate_bytes(n_values, last_dim) \
            + AUX_BYTES

    def estimate_round_payload(self, n_values: float,
                               last_dim: int = 0) -> float:
        """Analytic up+down payload bytes for n_values cut-layer elements
        each way — for devices whose tensors are never materialized
        (warm-up observation of non-participants)."""
        return (self.feature_codec.estimate_bytes(n_values, last_dim)
                + self.grad_codec.estimate_bytes(n_values, last_dim)
                + 2 * AUX_BYTES)

    def analytic_round_time(self, dev, *, wc_size: float, n_values: float,
                            fc: float, fs: float, t: float):
        """Eq.-1 device-round (time, bytes) from analytic payloads: the
        single formula shared by the engine's warm-up branch, the
        benchmark sweep, and the scheduler tests — change the payload
        convention here and every consumer follows."""
        from repro.core.simulation import (device_round_time_bytes,
                                           model_dispatch_bytes)
        nbytes = model_dispatch_bytes(wc_size=wc_size) \
            + self.estimate_round_payload(n_values)
        t_round = device_round_time_bytes(dev, comm_bytes=nbytes, fc=fc,
                                          fs=fs, rate=self.rate(dev, t)) \
            + MESSAGES_PER_ROUND * self.latency
        return t_round, nbytes

    def rate(self, dev, t: float) -> float:
        return self.link.rate(dev, t)

    def mean_rate(self, dev, t0: float, t1: float) -> float:
        """Average link rate over [t0, t1] (predictive forecasts price a
        transfer spanning the projected window with this); links without
        a mean fall back to the instantaneous rate at t0."""
        if hasattr(self.link, "mean_rate"):
            return self.link.mean_rate(dev, t0, t1)
        return self.link.rate(dev, t0)
