"""CommChannel — the metered transport between devices and the Main
Server.

Everything that crosses the cut goes through here: uplink features
(step 4 of Fig. 1) and downlink feature-gradients (step 7). The channel
(a) applies the codec round-trip so the receiver trains on exactly what
the wire delivered, and (b) meters exact payload bytes per direction and
per device-round, which the engine's Eq.-1 tick converts to transfer
time using the link model's rate at the current simulated clock.

Byte convention (comm/README.md): payload bytes are exact from the
encoded arrays. Model dispatch/collection defaults to fp32
(``elements * BYTES_PER_ELEM``, matching the paper's Eq.-1 structure);
with a non-fp32 ``dispatch_codec`` the Wc legs cross the wire through
that codec too — the engine routes the client-portion parameters
through ``dispatch_leaves`` / ``collect_leaves`` so dispatch
compression error reaches training and the legs are metered exactly.

``error_feedback=True`` turns the channel stateful: per-(device,
direction) residual accumulators hold the compression error of the last
transfer and add it back before the next encode (SEC/EF-style), so
quantization/sparsification error is compensated across rounds instead
of dropped. A residual is keyed by direction + device (+ leaf index for
model legs) and resets whenever the tensor shape changes (a re-split
changes the cut). fp32 stays bit-exact: its round-trip error is zero,
so the accumulators never hold anything.

Two transport-delay knobs ride on the channel (both default off, so the
fp32/static seed regime is untouched):

``latency``          per-message seconds. A device-round exchanges four
                     messages (Wc dispatch, features up, gradients down,
                     Wc collect), so the atomic Eq.-1 time gains
                     ``4 * latency``; the phase pipeline charges two
                     latencies to the upload phase and two to the
                     download phase. With a non-constant
                     ``latency_dist`` each device-round draws its own
                     latency around this mean (``links.LatencySampler``,
                     deterministic per (seed, device, round) — the
                     driver advances ``sim_round``).
``uplink_capacity``  the Main Server's shared ingress in Table-1
                     elements/s (0 = uncontended). Only the phase-level
                     pipeline can observe overlap, so contention prices
                     only pipelined timelines — see
                     ``links.shared_link_finish_times`` /
                     ``links.FluidLink``.
``downlink_capacity`` the Main Server's shared egress (elements/s, 0 =
                     uncontended): concurrent dfx downloads in the
                     pipeline contend for it with the same max-min fair
                     fluid schedule as the uplink.
"""
from __future__ import annotations

import copy

from repro.comm.codecs import Codec, get_codec
from repro.comm.links import LatencySampler, StaticLink

AUX_BYTES = 4.0          # the scalar aux-loss rider on each feature msg
MESSAGES_PER_ROUND = 4   # dispatch, features up, grads down, collect


class CommChannel:
    def __init__(self, codec="fp32", grad_codec=None, link=None, *,
                 dispatch_codec="fp32", error_feedback: bool = False,
                 topk_frac: float = None,
                 latency: float = 0.0, uplink_capacity: float = 0.0,
                 downlink_capacity: float = 0.0,
                 latency_dist: str = "constant",
                 latency_jitter: float = 0.5, latency_seed: int = 0):
        def _codec(c, role):
            if not isinstance(c, Codec):
                c = get_codec(c, topk_frac=topk_frac)
                if getattr(c, "name", "") == "randk":
                    # decorrelate the index masks of the up / down /
                    # dispatch legs (same seed + lock-stepped call
                    # counters would drop features and their gradients
                    # at identical positions)
                    c.seed = role
            if error_feedback and getattr(c, "name", "") == "randk" \
                    and c.unbiased:
                # the n/k-scaled operator is not a contraction and
                # makes the feedback accumulators diverge; the residual
                # re-injection compensates the bias instead. Copy a
                # caller-supplied instance rather than mutating it.
                c = copy.copy(c)
                c.unbiased = False
            return c

        self.feature_codec = _codec(codec, 0)
        if grad_codec is None or grad_codec == "":
            grad_codec = self.feature_codec.name
        self.grad_codec = _codec(grad_codec, 1)
        self.dispatch_codec = _codec(dispatch_codec or "fp32", 2)
        self.error_feedback = bool(error_feedback)
        self.link = link or StaticLink()
        if latency < 0:
            raise ValueError(f"latency must be >= 0: {latency}")
        if uplink_capacity < 0:
            raise ValueError(
                f"uplink_capacity must be >= 0 (0 = uncontended): "
                f"{uplink_capacity}")
        if downlink_capacity < 0:
            raise ValueError(
                f"downlink_capacity must be >= 0 (0 = uncontended): "
                f"{downlink_capacity}")
        self.latency = float(latency)
        self.latency_sampler = LatencySampler(
            latency, latency_dist, latency_jitter, latency_seed)
        self.sim_round = 0           # advanced by the RoundDriver
        self.uplink_capacity = float(uplink_capacity)
        self.downlink_capacity = float(downlink_capacity)
        self.up_bytes = 0.0          # device -> server (features)
        self.down_bytes = 0.0        # server -> device (dfx)
        self.disp_up_bytes = 0.0     # device -> server (Wc/update collect)
        self.disp_down_bytes = 0.0   # server -> device (Wc dispatch)
        self._round_up = {}          # cid -> uplink payload bytes this round
        self._round_down = {}        # cid -> downlink payload bytes
        self._round_disp_up = {}     # cid -> collect-leg bytes this round
        self._round_disp_down = {}   # cid -> dispatch-leg bytes
        self._residuals = {}         # (direction, cid[, leaf]) -> tensor
        # fault injection: a killed device's residuals sit here until it
        # rejoins (restored) or forever (discarded, with metered mass)
        self._quarantine = {}        # cid -> {residual key: tensor}
        self.ef_discarded_mass = 0.0  # L2 mass of discarded residuals
        # observability: an observe.TraceRecorder injected by the
        # engine/caller (None or disabled = zero overhead — the wire
        # hooks guard before touching it)
        self.recorder = None

    # --------------------------------------------------- error feedback
    @property
    def dispatch_passthrough(self) -> bool:
        """True when the model legs need no tensor round-trip at all:
        fp32 is lossless, so there is no compression error to inject or
        feed back regardless of ``error_feedback``. The engine then
        skips the dispatch/collect walk entirely and cost models price
        the legs analytically (identical bytes), which keeps the seed
        path bit-exact by construction."""
        return self.dispatch_codec.name == "fp32"

    def _ef_roundtrip(self, codec, key, x):
        """Codec round-trip with the residual accumulator folded in:
        the error of THIS transfer is held under ``key`` and added back
        before the NEXT transfer's encode. Without error feedback —
        or for lossless fp32, whose residual is identically zero — this
        is a plain round-trip."""
        if not self.error_feedback or codec.name == "fp32":
            return codec.roundtrip(x)
        r = self._residuals.get(key)
        if r is not None and r.shape == x.shape:
            x = x + r.astype(x.dtype)
        y, nbytes = codec.roundtrip(x)
        self._residuals[key] = x - y
        return y, nbytes

    def residual_norm(self) -> float:
        """Total L2 mass currently held by the feedback accumulators
        (0.0 when feedback is off or nothing has been dropped yet)."""
        import jax.numpy as jnp
        return float(sum(jnp.sum(jnp.asarray(r, jnp.float32) ** 2) ** 0.5
                         for r in self._residuals.values()))

    def residual_norm_of(self, cid) -> float:
        """L2 mass of the feedback accumulators a single device holds
        (residual keys are (direction, cid[, leaf]))."""
        import jax.numpy as jnp
        return float(sum(jnp.sum(jnp.asarray(r, jnp.float32) ** 2) ** 0.5
                         for k, r in self._residuals.items()
                         if k[1] == cid))

    def residual_elements_of(self, cid) -> float:
        """Element count of the device's live feedback accumulators —
        what a cut-layer re-split would discard (shape change resets
        the residual), priced by the resource-aware forecast as bytes
        that must cross the wire again."""
        return float(sum(r.size for k, r in self._residuals.items()
                         if k[1] == cid))

    def reset_feedback(self):
        self._residuals = {}

    # ------------------------------------------- residual fault handling
    def quarantine_residuals(self, cid):
        """A device died: move every feedback accumulator it owns out of
        the live set (its next transfer — if it ever rejoins — must not
        re-inject error from its dead incarnation until the plan's
        residual policy decides). Residual keys are (direction, cid[,
        leaf]); everything keyed to ``cid`` moves. Idempotent per kill:
        a second quarantine before release merges into the held set."""
        moved = {k: v for k, v in self._residuals.items() if k[1] == cid}
        if moved:
            for k in moved:
                del self._residuals[k]
            self._quarantine.setdefault(cid, {}).update(moved)

    def release_residuals(self, cid, *, restore: bool = True):
        """The device rejoined. ``restore=True`` puts its quarantined
        accumulators back live (compression error from the dead
        incarnation is compensated as if nothing happened — valid
        because the residual is additive error state, not model state);
        ``restore=False`` discards them, metering the dropped L2 mass
        in ``ef_discarded_mass`` so the loss is observable, not silent.
        A device with nothing quarantined is a no-op."""
        held = self._quarantine.pop(cid, None)
        if not held:
            return
        if restore:
            # live state under the same key wins: the rejoined device
            # may already have fresh residuals from its new incarnation
            for k, v in held.items():
                self._residuals.setdefault(k, v)
        else:
            import jax.numpy as jnp
            self.ef_discarded_mass += float(
                sum(jnp.sum(jnp.asarray(r, jnp.float32) ** 2) ** 0.5
                    for r in held.values()))

    # ------------------------------------------------------ codec state
    def _stateful_codecs(self):
        return (("feature", self.feature_codec),
                ("grad", self.grad_codec),
                ("dispatch", self.dispatch_codec))

    def export_codec_state(self) -> dict:
        """Snapshot the replayable state of any stateful codec (rand-k's
        per-call counter stream) for checkpoint/resume: restoring it
        makes every subsequent index draw identical to an uninterrupted
        run."""
        return {role: c.state() for role, c in self._stateful_codecs()
                if hasattr(c, "state")}

    def restore_codec_state(self, state: dict):
        for role, c in self._stateful_codecs():
            if role in state and hasattr(c, "set_state"):
                c.set_state(state[role])

    def reset_codecs(self):
        """Rewind every stateful codec to the start of its stream."""
        for _, c in self._stateful_codecs():
            if hasattr(c, "reset"):
                c.reset()

    # ------------------------------------------------- checkpoint state
    def export_state(self) -> dict:
        """JSON-safe channel state for full-run checkpoints: cumulative
        byte meters, the simulated round the latency sampler keys on,
        discarded-residual mass, and every stateful codec's stream
        position. Residual TENSORS travel separately (they are arrays —
        see ``export_residual_state``); config knobs are reconstructed
        by the caller."""
        return {"sim_round": self.sim_round,
                "up_bytes": self.up_bytes,
                "down_bytes": self.down_bytes,
                "disp_up_bytes": self.disp_up_bytes,
                "disp_down_bytes": self.disp_down_bytes,
                "ef_discarded_mass": self.ef_discarded_mass,
                "codecs": self.export_codec_state()}

    def restore_state(self, st: dict):
        self.sim_round = int(st["sim_round"])
        self.up_bytes = float(st["up_bytes"])
        self.down_bytes = float(st["down_bytes"])
        self.disp_up_bytes = float(st["disp_up_bytes"])
        self.disp_down_bytes = float(st["disp_down_bytes"])
        self.ef_discarded_mass = float(st["ef_discarded_mass"])
        self.restore_codec_state(st.get("codecs", {}))

    def export_residual_state(self) -> dict:
        """Flatten live + quarantined feedback accumulators to a
        {string name: array} dict an ``.npz`` can hold: live keys become
        ``"r:" + json([direction, cid, leaf?])``, quarantined ones
        ``"q:" + json([cid, [direction, cid, leaf?]])`` (np-integer cids
        coerced to plain ints — they hash/compare equal on restore)."""
        import json

        def _py(o):
            return o.item() if hasattr(o, "item") else o

        out = {}
        for k, v in self._residuals.items():
            out["r:" + json.dumps([_py(p) for p in k])] = v
        for cid, held in self._quarantine.items():
            for k, v in held.items():
                out["q:" + json.dumps([_py(cid),
                                       [_py(p) for p in k]])] = v
        return out

    def restore_residual_state(self, flat: dict):
        import json
        self._residuals = {}
        self._quarantine = {}
        for name, v in flat.items():
            tag, payload = name[:2], json.loads(name[2:])
            if tag == "r:":
                self._residuals[tuple(payload)] = v
            elif tag == "q:":
                cid, key = payload
                self._quarantine.setdefault(cid, {})[tuple(key)] = v
            else:
                raise ValueError(f"unknown residual entry {name!r}")

    # ------------------------------------------------------------ wire
    def _xfer(self, codec, cid, msg, meter, direction):
        """msg: {'h': tensor, ...riders} or bare tensor."""
        if isinstance(msg, dict):
            h, nbytes = self._ef_roundtrip(codec, (direction, cid),
                                           msg["h"])
            out = dict(msg, h=h)
            nbytes += AUX_BYTES * (len(msg) - 1)
        else:
            out, nbytes = self._ef_roundtrip(codec, (direction, cid), msg)
        meter[cid] = meter.get(cid, 0.0) + nbytes
        rec = self.recorder
        if rec is not None and rec.enabled:
            rec.count(f"comm.{direction}.msgs")
            rec.count(f"comm.{direction}.bytes", nbytes)
        return out, nbytes

    def uplink_features(self, cid, feats):
        """Device cid uploads its cut-layer features. Returns what the
        server receives (codec round-trip applied)."""
        out, nbytes = self._xfer(self.feature_codec, cid, feats,
                                 self._round_up, "up")
        self.up_bytes += nbytes
        return out

    def downlink_grads(self, cid, dfx):
        """Server returns the feature gradient to device cid."""
        out, nbytes = self._xfer(self.grad_codec, cid, dfx,
                                 self._round_down, "down")
        self.down_bytes += nbytes
        return out

    # -------------------------------------------------- batched cohort
    def _xfer_cohort(self, codec, pairs, meter, direction):
        """One fused call for a cohort flushed together. ``pairs``:
        [(cid, msg)] in the order the sequential path would have sent
        them. Metering, recorder counts and residual mutations are the
        sequential semantics exactly (see comm/fused.py's contract);
        unsupported codecs or singleton cohorts just loop ``_xfer``."""
        from repro.comm import fused
        if not fused.supports(codec) or len(pairs) < 2:
            return [self._xfer(codec, cid, msg, meter, direction)
                    for cid, msg in pairs]
        items = [((direction, cid),
                  msg["h"] if isinstance(msg, dict) else msg)
                 for cid, msg in pairs]
        results = fused.cohort_roundtrip(codec, items, self._residuals,
                                         self.error_feedback)
        rec = self.recorder
        out = []
        for (cid, msg), (h, nbytes) in zip(pairs, results):
            if isinstance(msg, dict):
                nbytes += AUX_BYTES * (len(msg) - 1)
                out.append((dict(msg, h=h), nbytes))
            else:
                out.append((h, nbytes))
            meter[cid] = meter.get(cid, 0.0) + nbytes
            if rec is not None and rec.enabled:
                rec.count(f"comm.{direction}.msgs")
                rec.count(f"comm.{direction}.bytes", nbytes)
        return out

    def uplink_features_cohort(self, pairs):
        """Batched ``uplink_features``: pairs = [(cid, feats)], returns
        what the server receives for each, in order."""
        results = self._xfer_cohort(self.feature_codec, pairs,
                                    self._round_up, "up")
        for _, nbytes in results:
            self.up_bytes += nbytes
        return [out for out, _ in results]

    def downlink_grads_cohort(self, pairs):
        """Batched ``downlink_grads``: pairs = [(cid, dfx)]."""
        results = self._xfer_cohort(self.grad_codec, pairs,
                                    self._round_down, "down")
        for _, nbytes in results:
            self.down_bytes += nbytes
        return [out for out, _ in results]

    # ------------------------------------------------------ model legs
    def dispatch_leaves(self, cid, leaves):
        """Server -> device: the Wc dispatch leg (or the FedAvg model
        broadcast). Each leaf crosses the wire through the dispatch
        codec; exact bytes are metered per device-round. Residual keys
        carry the leaf index so per-(device, tensor) feedback state
        survives across rounds (and resets on shape changes)."""
        return self._model_leg(cid, leaves, "disp_down",
                               self._round_disp_down)

    def collect_leaves(self, cid, leaves):
        """Device -> server: the updated-Wc collect leg (or the FedAvg
        QSGD-style update upload)."""
        return self._model_leg(cid, leaves, "disp_up",
                               self._round_disp_up)

    def dispatch_leaves_cohort(self, pairs):
        """Batched Wc dispatch: pairs = [(cid, leaves)], one fused call
        for the whole cohort's client portions (leaves flattened in
        (cid, leaf-index) order — the sequential transfer order)."""
        return self._model_leg_cohort(pairs, "disp_down",
                                      self._round_disp_down)

    def collect_leaves_cohort(self, pairs):
        """Batched updated-Wc collect leg."""
        return self._model_leg_cohort(pairs, "disp_up",
                                      self._round_disp_up)

    def _model_leg_cohort(self, pairs, direction, meter):
        if self.dispatch_passthrough:
            return [list(leaves) for _, leaves in pairs]
        from repro.comm import fused
        if not fused.supports(self.dispatch_codec) or len(pairs) < 2:
            return [self._model_leg(cid, leaves, direction, meter)
                    for cid, leaves in pairs]
        items = [((direction, cid, i), x)
                 for cid, leaves in pairs
                 for i, x in enumerate(leaves)]
        results = fused.cohort_roundtrip(self.dispatch_codec, items,
                                         self._residuals,
                                         self.error_feedback)
        rec = self.recorder
        outs, pos = [], 0
        for cid, leaves in pairs:
            ys, nbytes = [], 0.0
            for _ in leaves:
                y, b = results[pos]
                pos += 1
                ys.append(y)
                nbytes += b
            meter[cid] = meter.get(cid, 0.0) + nbytes
            if direction == "disp_down":
                self.disp_down_bytes += nbytes
            else:
                self.disp_up_bytes += nbytes
            if rec is not None and rec.enabled:
                rec.count(f"comm.{direction}.msgs")
                rec.count(f"comm.{direction}.bytes", nbytes)
            outs.append(ys)
        return outs

    def _model_leg(self, cid, leaves, direction, meter):
        if self.dispatch_passthrough:
            return list(leaves)
        out = []
        nbytes = 0.0
        for i, x in enumerate(leaves):
            y, b = self._ef_roundtrip(self.dispatch_codec,
                                      (direction, cid, i), x)
            out.append(y)
            nbytes += b
        meter[cid] = meter.get(cid, 0.0) + nbytes
        if direction == "disp_down":
            self.disp_down_bytes += nbytes
        else:
            self.disp_up_bytes += nbytes
        rec = self.recorder
        if rec is not None and rec.enabled:
            rec.count(f"comm.{direction}.msgs")
            rec.count(f"comm.{direction}.bytes", nbytes)
        return out

    # ------------------------------------------------------- accounting
    @property
    def total_bytes(self) -> float:
        return self.up_bytes + self.down_bytes \
            + self.disp_up_bytes + self.disp_down_bytes

    def round_payload(self, cid) -> float:
        """Exact cut-layer payload bytes metered for cid since the last
        reset (model legs are under ``round_dispatch``)."""
        return self._round_up.get(cid, 0.0) \
            + self._round_down.get(cid, 0.0)

    def round_payload_split(self, cid):
        """(uplink, downlink) payload bytes metered for cid this round —
        the per-direction split the phase pipeline prices."""
        return (self._round_up.get(cid, 0.0),
                self._round_down.get(cid, 0.0))

    def round_dispatch(self, cid) -> float:
        """Exact model-leg bytes (Wc dispatch + collect) metered for cid
        this round; 0.0 on the fp32 passthrough (cost models then price
        the legs analytically — identical by construction)."""
        return self._round_disp_up.get(cid, 0.0) \
            + self._round_disp_down.get(cid, 0.0)

    def round_dispatch_split(self, cid):
        """(dispatch-down, collect-up) model-leg bytes for cid."""
        return (self._round_disp_down.get(cid, 0.0),
                self._round_disp_up.get(cid, 0.0))

    def reset_round(self):
        self._round_up = {}
        self._round_down = {}
        self._round_disp_up = {}
        self._round_disp_down = {}

    def estimate_uplink_payload(self, n_values: float,
                                last_dim: int = 0) -> float:
        """Analytic uplink (feature) payload bytes for n_values cut-layer
        elements — the upload phase's wire traffic."""
        return self.feature_codec.estimate_bytes(n_values, last_dim) \
            + AUX_BYTES

    def estimate_downlink_payload(self, n_values: float,
                                  last_dim: int = 0) -> float:
        """Analytic downlink (feature-gradient) payload bytes."""
        return self.grad_codec.estimate_bytes(n_values, last_dim) \
            + AUX_BYTES

    def estimate_round_payload(self, n_values: float,
                               last_dim: int = 0) -> float:
        """Analytic up+down payload bytes for n_values cut-layer elements
        each way — for devices whose tensors are never materialized
        (warm-up observation of non-participants)."""
        return (self.feature_codec.estimate_bytes(n_values, last_dim)
                + self.grad_codec.estimate_bytes(n_values, last_dim)
                + 2 * AUX_BYTES)

    def estimate_dispatch_leg(self, wc_size: float) -> float:
        """Analytic one-way model-leg bytes for a wc_size-element client
        portion under the dispatch codec (fp32 reproduces the seed's
        ``wc_size * BYTES_PER_ELEM``)."""
        return self.dispatch_codec.estimate_bytes(wc_size)

    def estimate_dispatch_round(self, wc_size: float) -> float:
        """Dispatch + collect legs (the Eq.-1 ``2|Wc|`` term, now priced
        through the dispatch codec)."""
        return 2.0 * self.estimate_dispatch_leg(wc_size)

    def latency_of(self, cid) -> float:
        """This device-round's per-message latency: the constant knob
        unless a distribution is configured, in which case the draw is
        seeded by (latency_seed, cid, sim_round) — deterministic under
        replay, identical across re-pricings of the same round."""
        return self.latency_sampler.sample(cid, self.sim_round)

    def analytic_round_time(self, dev, *, wc_size: float, n_values: float,
                            fc: float, fs: float, t: float):
        """Eq.-1 device-round (time, bytes) from analytic payloads: the
        single formula shared by the engine's warm-up branch, the
        benchmark sweep, and the scheduler tests — change the payload
        convention here and every consumer follows."""
        from repro.core.simulation import device_round_time_bytes
        nbytes = self.estimate_dispatch_round(wc_size) \
            + self.estimate_round_payload(n_values)
        t_round = device_round_time_bytes(dev, comm_bytes=nbytes, fc=fc,
                                          fs=fs, rate=self.rate(dev, t)) \
            + MESSAGES_PER_ROUND * self.latency_of(dev.cid)
        return t_round, nbytes

    def rate(self, dev, t: float) -> float:
        return self.link.rate(dev, t)

    def mean_rate(self, dev, t0: float, t1: float) -> float:
        """Average link rate over [t0, t1] (predictive forecasts price a
        transfer spanning the projected window with this); links without
        a mean fall back to the instantaneous rate at t0."""
        if hasattr(self.link, "mean_rate"):
            return self.link.mean_rate(dev, t0, t1)
        return self.link.rate(dev, t0)
