"""Link models — what transfer rate a device sees at simulated time t —
plus the shared-uplink contention scheduler.

``StaticLink`` is the paper's Table-1 regime (each device keeps its fixed
elements/s rate forever). ``LinkTrace`` is trace-driven: a
piecewise-constant multiplier schedule on top of each device's base rate,
wrapped modulo a period, with an optional per-device phase so devices
fade independently — rounds later in the Eq.-1 clock see different link
quality, and the sliding scheduler's client time table tracks it.

Trace format (see comm/README.md): ascending ``times`` anchors starting
at 0.0 and same-length ``multipliers``; segment i covers
[times[i], times[i+1]) and the last segment runs to ``period`` (default:
``times[-1]`` extended by the previous segment's width, so the final
multiplier always gets a non-empty segment). JSON traces are
``{"times": [...], "multipliers": [...], "period": ...}``.

``shared_link_finish_times`` is the contention model for the phase-level
pipeline (core/driver.py): concurrent uploads to the Main Server share a
finite ingress capacity, split max-min fairly among the active transfers
with each transfer also capped by its device's own link rate. It is a
fluid (processor-sharing) simulation: whenever a transfer starts or
finishes the fair shares are recomputed, so an upload that overlaps many
others is stretched exactly by the observed congestion.
"""
from __future__ import annotations

import bisect
import json
import math

import numpy as np

# Golden-ratio stride decorrelates per-device phases without RNG state.
_PHI = 0.6180339887498949


class StaticLink:
    name = "static"

    def rate(self, dev, t: float) -> float:
        """elements/s for device ``dev`` at simulated time ``t``."""
        return dev.rate

    def mean_rate(self, dev, t0: float, t1: float) -> float:
        """Average rate over [t0, t1] (constant for a static link) —
        what the predictive scheduler forecast prices a transfer with."""
        return dev.rate


class LinkTrace:
    name = "trace"

    def __init__(self, times, multipliers, *, period: float = 0.0,
                 per_device_phase: bool = True):
        times = [float(x) for x in times]
        multipliers = [float(m) for m in multipliers]
        if not times or len(times) != len(multipliers):
            raise ValueError(
                "LinkTrace needs same-length non-empty times/multipliers "
                "(link='trace' requires trace_file or trace_times); got "
                f"{len(times)} times, {len(multipliers)} multipliers")
        if times[0] != 0.0 or times != sorted(times):
            raise ValueError(f"trace times must ascend from 0.0: {times}")
        if any(m <= 0 for m in multipliers):
            raise ValueError(f"trace multipliers must be > 0: "
                             f"{multipliers}")
        self.times = times
        self.multipliers = multipliers
        if not period:
            # the last anchor opens a segment too: extend it by the
            # previous segment's width (period == times[-1] would make
            # it zero-length and silently drop the final multiplier)
            period = times[-1] + (times[-1] - times[-2]) \
                if len(times) > 1 else 1.0
        self.period = float(period)
        if len(times) > 1 and self.period <= times[-1]:
            raise ValueError(
                f"period {self.period} must exceed the last anchor "
                f"{times[-1]} or its multiplier would never apply")
        self.per_device_phase = per_device_phase
        # cumulative ∫ multiplier over one period, for mean_rate: the
        # last anchor's segment runs to ``period``
        widths = [self.times[i + 1] - self.times[i]
                  for i in range(len(self.times) - 1)]
        widths.append(self.period - self.times[-1])
        self._cum = [0.0]
        for w, m in zip(widths, self.multipliers):
            self._cum.append(self._cum[-1] + w * m)
        self._period_integral = self._cum[-1]

    def multiplier_at(self, t: float, phase: float = 0.0) -> float:
        t = (t + phase) % self.period
        i = bisect.bisect_right(self.times, t) - 1
        return self.multipliers[max(i, 0)]

    def _integral(self, t: float) -> float:
        """∫_0^t multiplier, t unwrapped (t >= 0)."""
        full, rem = divmod(t, self.period)
        i = max(bisect.bisect_right(self.times, rem) - 1, 0)
        return full * self._period_integral + self._cum[i] \
            + self.multipliers[i] * (rem - self.times[i])

    def mean_multiplier(self, t0: float, t1: float,
                        phase: float = 0.0) -> float:
        """Exact time-average of the multiplier over [t0, t1]."""
        if t1 <= t0:
            return self.multiplier_at(t0, phase)
        return (self._integral(t1 + phase) - self._integral(t0 + phase)) \
            / (t1 - t0)

    def mean_rate(self, dev, t0: float, t1: float) -> float:
        """Average elements/s over [t0, t1] — the predictive scheduler
        prices a transfer spanning the projected completion window with
        this instead of the instantaneous rate at dispatch."""
        return dev.rate * self.mean_multiplier(t0, t1,
                                               self._phase(dev.cid))

    def _phase(self, cid) -> float:
        if not self.per_device_phase:
            return 0.0
        return (int(cid) * _PHI % 1.0) * self.period

    def rate(self, dev, t: float) -> float:
        return dev.rate * self.multiplier_at(t, self._phase(dev.cid))

    # ------------------------------------------------------------- io
    @classmethod
    def from_file(cls, path: str, **kw) -> "LinkTrace":
        with open(path) as f:
            spec = json.load(f)
        return cls(spec["times"], spec["multipliers"],
                   period=spec.get("period", 0.0), **kw)

    @classmethod
    def fading(cls, *, n_segments: int = 8, period: float = 400.0,
               lo: float = 0.1, hi: float = 1.0, seed: int = 0,
               per_device_phase: bool = True) -> "LinkTrace":
        """Synthetic deep-fade trace: log-uniform multipliers in [lo, hi]."""
        rng = np.random.default_rng(seed)
        times = [period * i / n_segments for i in range(n_segments)]
        mult = np.exp(rng.uniform(np.log(lo), np.log(hi), n_segments))
        return cls(times, mult.tolist(), period=period,
                   per_device_phase=per_device_phase)


# ---------------------------------------------------------------------------
# shared-uplink contention (the phase pipeline's upload scheduler)
# ---------------------------------------------------------------------------
def _maxmin_rates(active, caps, capacity):
    """Max-min fair allocation of ``capacity`` among ``active`` jobs,
    each additionally capped by its own ``caps[i]`` rate: jobs are
    water-filled from the smallest cap up, so a slow device never blocks
    a fast one from using the leftover capacity."""
    if math.isinf(capacity):
        return {i: caps[i] for i in active}
    rates = {}
    left, k = capacity, len(active)
    for i in sorted(active, key=lambda j: caps[j]):
        r = min(caps[i], left / k)
        rates[i] = r
        left -= r
        k -= 1
    return rates


def shared_link_finish_times(jobs, capacity=math.inf):
    """Finish times of transfer jobs on a shared link (fluid max-min
    fair processor sharing).

    jobs: sequence of ``(arrival_s, size_bytes, own_rate_bytes_per_s)``;
    capacity: the link's total bytes/s (``math.inf`` = uncontended, each
    job runs at its own rate). Returns finish times in job order. With
    infinite capacity this degenerates exactly to
    ``arrival + size / own_rate``.
    """
    n = len(jobs)
    if n == 0:
        return []
    if capacity <= 0:
        raise ValueError(f"shared link capacity must be > 0: {capacity}")
    arrive = [float(a) for a, _, _ in jobs]
    left = [float(b) for _, b, _ in jobs]
    caps = [float(r) for _, _, r in jobs]
    if any(r <= 0 for r in caps):
        raise ValueError(f"job rate caps must be > 0: {caps}")
    finish = [0.0] * n
    done_eps = [max(1e-9, 1e-12 * b) for b in left]
    todo = set(range(n))
    for i in list(todo):               # zero-byte jobs land on arrival
        if left[i] <= done_eps[i]:
            finish[i] = arrive[i]
            todo.discard(i)
    if not todo:
        return finish
    t = min(arrive[i] for i in todo)
    while todo:
        active = [i for i in todo if arrive[i] <= t]
        if not active:
            t = min(arrive[i] for i in todo)
            continue
        rates = _maxmin_rates(active, caps, capacity)
        t_fin = min(t + left[i] / rates[i] for i in active)
        future = [arrive[i] for i in todo if arrive[i] > t]
        t_next = min([t_fin] + ([min(future)] if future else []))
        for i in active:
            left[i] -= rates[i] * (t_next - t)
        t = t_next
        for i in active:
            if left[i] <= done_eps[i]:
                finish[i] = t
                todo.discard(i)
    return finish


def get_link(name: str = "static", **kw):
    if name == "static":
        return StaticLink()
    if name == "trace":
        return LinkTrace(**kw)
    raise KeyError(f"unknown link model {name!r}; known: static, trace")
