"""Link models — what transfer rate a device sees at simulated time t —
plus the shared-uplink contention scheduler.

``StaticLink`` is the paper's Table-1 regime (each device keeps its fixed
elements/s rate forever). ``LinkTrace`` is trace-driven: a
piecewise-constant multiplier schedule on top of each device's base rate,
wrapped modulo a period, with an optional per-device phase so devices
fade independently — rounds later in the Eq.-1 clock see different link
quality, and the sliding scheduler's client time table tracks it.

Trace format (see comm/README.md): ascending ``times`` anchors starting
at 0.0 and same-length ``multipliers``; segment i covers
[times[i], times[i+1]) and the last segment runs to ``period`` (default:
``times[-1]`` extended by the previous segment's width, so the final
multiplier always gets a non-empty segment). JSON traces are
``{"times": [...], "multipliers": [...], "period": ...}``.

``shared_link_finish_times`` is the contention model for the phase-level
pipeline (core/driver.py): concurrent uploads to the Main Server share a
finite ingress capacity, split max-min fairly among the active transfers
with each transfer also capped by its device's own link rate. It is a
fluid (processor-sharing) simulation: whenever a transfer starts or
finishes the fair shares are recomputed, so an upload that overlaps many
others is stretched exactly by the observed congestion.

``FluidLink`` wraps the same fluid schedule in a *stateful* per-link
object that carries in-flight flows ACROSS dispatch cohorts: every flow
ever submitted stays in the system and each ``solve()`` re-runs the
max-min fair schedule over all of them, so a straggler's transfer from
an earlier aggregation window contends with (and is slowed by) the next
window's cohort. ``LatencySampler`` draws per-(device, round) message
latencies from a configurable mean-preserving distribution with a
deterministic seed per draw.
"""
from __future__ import annotations

import bisect
import json
import math
import zlib

import numpy as np

# Golden-ratio stride decorrelates per-device phases without RNG state.
_PHI = 0.6180339887498949


class StaticLink:
    name = "static"

    def rate(self, dev, t: float) -> float:
        """elements/s for device ``dev`` at simulated time ``t``."""
        return dev.rate

    def mean_rate(self, dev, t0: float, t1: float) -> float:
        """Average rate over [t0, t1] (constant for a static link) —
        what the predictive scheduler forecast prices a transfer with."""
        return dev.rate


class LinkTrace:
    name = "trace"

    def __init__(self, times, multipliers, *, period: float = 0.0,
                 per_device_phase: bool = True):
        times = [float(x) for x in times]
        multipliers = [float(m) for m in multipliers]
        if not times or len(times) != len(multipliers):
            raise ValueError(
                "LinkTrace needs same-length non-empty times/multipliers "
                "(link='trace' requires trace_file or trace_times); got "
                f"{len(times)} times, {len(multipliers)} multipliers")
        if times[0] != 0.0 or times != sorted(times):
            raise ValueError(f"trace times must ascend from 0.0: {times}")
        if any(m <= 0 for m in multipliers):
            raise ValueError(f"trace multipliers must be > 0: "
                             f"{multipliers}")
        self.times = times
        self.multipliers = multipliers
        if not period:
            # the last anchor opens a segment too: extend it by the
            # previous segment's width (period == times[-1] would make
            # it zero-length and silently drop the final multiplier)
            period = times[-1] + (times[-1] - times[-2]) \
                if len(times) > 1 else 1.0
        self.period = float(period)
        if len(times) > 1 and self.period <= times[-1]:
            raise ValueError(
                f"period {self.period} must exceed the last anchor "
                f"{times[-1]} or its multiplier would never apply")
        self.per_device_phase = per_device_phase
        # cumulative ∫ multiplier over one period, for mean_rate: the
        # last anchor's segment runs to ``period``
        widths = [self.times[i + 1] - self.times[i]
                  for i in range(len(self.times) - 1)]
        widths.append(self.period - self.times[-1])
        self._cum = [0.0]
        for w, m in zip(widths, self.multipliers):
            self._cum.append(self._cum[-1] + w * m)
        self._period_integral = self._cum[-1]

    def multiplier_at(self, t: float, phase: float = 0.0) -> float:
        t = (t + phase) % self.period
        i = bisect.bisect_right(self.times, t) - 1
        return self.multipliers[max(i, 0)]

    def _integral(self, t: float) -> float:
        """∫_0^t multiplier, t unwrapped (t >= 0)."""
        full, rem = divmod(t, self.period)
        i = max(bisect.bisect_right(self.times, rem) - 1, 0)
        return full * self._period_integral + self._cum[i] \
            + self.multipliers[i] * (rem - self.times[i])

    def mean_multiplier(self, t0: float, t1: float,
                        phase: float = 0.0) -> float:
        """Exact time-average of the multiplier over [t0, t1]."""
        if t1 <= t0:
            return self.multiplier_at(t0, phase)
        return (self._integral(t1 + phase) - self._integral(t0 + phase)) \
            / (t1 - t0)

    def mean_rate(self, dev, t0: float, t1: float) -> float:
        """Average elements/s over [t0, t1] — the predictive scheduler
        prices a transfer spanning the projected completion window with
        this instead of the instantaneous rate at dispatch."""
        return dev.rate * self.mean_multiplier(t0, t1,
                                               self._phase(dev.cid))

    def _phase(self, cid) -> float:
        if not self.per_device_phase:
            return 0.0
        return (int(cid) * _PHI % 1.0) * self.period

    def rate(self, dev, t: float) -> float:
        return dev.rate * self.multiplier_at(t, self._phase(dev.cid))

    # ------------------------------------------------------------- io
    @classmethod
    def from_file(cls, path: str, **kw) -> "LinkTrace":
        with open(path) as f:
            spec = json.load(f)
        return cls(spec["times"], spec["multipliers"],
                   period=spec.get("period", 0.0), **kw)

    @classmethod
    def fading(cls, *, n_segments: int = 8, period: float = 400.0,
               lo: float = 0.1, hi: float = 1.0, seed: int = 0,
               per_device_phase: bool = True) -> "LinkTrace":
        """Synthetic deep-fade trace: log-uniform multipliers in [lo, hi]."""
        rng = np.random.default_rng(seed)
        times = [period * i / n_segments for i in range(n_segments)]
        mult = np.exp(rng.uniform(np.log(lo), np.log(hi), n_segments))
        return cls(times, mult.tolist(), period=period,
                   per_device_phase=per_device_phase)


# ---------------------------------------------------------------------------
# shared-uplink contention (the phase pipeline's upload scheduler)
# ---------------------------------------------------------------------------
def _maxmin_rates(active, caps, capacity):
    """Max-min fair allocation of ``capacity`` among ``active`` jobs,
    each additionally capped by its own ``caps[i]`` rate: jobs are
    water-filled from the smallest cap up, so a slow device never blocks
    a fast one from using the leftover capacity."""
    if math.isinf(capacity):
        return {i: caps[i] for i in active}
    rates = {}
    left, k = capacity, len(active)
    for i in sorted(active, key=lambda j: caps[j]):
        r = min(caps[i], left / k)
        rates[i] = r
        left -= r
        k -= 1
    return rates


def fluid_schedule(jobs, capacity=math.inf, until=None):
    """Fluid max-min fair processor-sharing schedule of transfer jobs on
    one shared link.

    jobs: sequence of ``(arrival_s, size_bytes, own_rate_bytes_per_s)``;
    capacity: the link's total bytes/s (``math.inf`` = uncontended, each
    job runs at its own rate). Returns ``(finish, remaining)`` in job
    order: with ``until=None`` the schedule runs to completion
    (``remaining`` all zero); with a finite ``until`` the simulation is
    right-censored there — unfinished jobs report ``math.inf`` and their
    bytes still in flight at ``until`` (the cross-window byte-
    conservation quantity the property suite checks).

    With infinite capacity jobs never interact and the schedule is the
    closed form ``arrival + size / own_rate`` — bit-exact with the
    uncontended seed path.
    """
    n = len(jobs)
    if n == 0:
        return [], []
    if capacity <= 0:
        raise ValueError(f"shared link capacity must be > 0: {capacity}")
    arrive = [float(a) for a, _, _ in jobs]
    left = [float(b) for _, b, _ in jobs]
    caps = [float(r) for _, _, r in jobs]
    if any(r <= 0 for r in caps):
        raise ValueError(f"job rate caps must be > 0: {caps}")
    if math.isinf(capacity):
        finish = [a + b / r for a, b, r in zip(arrive, left, caps)]
        if until is None:
            return finish, [0.0] * n
        rem = [b if a >= until else max(0.0, b - r * (until - a))
               for a, b, r in zip(arrive, left, caps)]
        return [f if f <= until else math.inf for f in finish], rem
    finish = [0.0] * n
    done_eps = [max(1e-9, 1e-12 * b) for b in left]
    todo = set(range(n))
    for i in list(todo):               # zero-byte jobs land on arrival
        if left[i] <= done_eps[i]:
            finish[i] = arrive[i]
            left[i] = 0.0
            todo.discard(i)
    if todo:
        t = min(arrive[i] for i in todo)
        while todo and not (until is not None and t >= until):
            active = [i for i in todo if arrive[i] <= t]
            if not active:
                t = min(arrive[i] for i in todo)
                continue
            rates = _maxmin_rates(active, caps, capacity)
            t_fin = min(t + left[i] / rates[i] for i in active)
            future = [arrive[i] for i in todo if arrive[i] > t]
            t_next = min([t_fin] + ([min(future)] if future else [])
                         + ([until] if until is not None else []))
            if t_next <= t:
                # FP-resolution guard: the nearest event is closer than
                # the clock's representable step at t (a carried flow's
                # tail can be sub-ulp once t is large), so time cannot
                # advance — the nearest job is done for all practical
                # purposes; land it at t to guarantee progress.
                i = min(active, key=lambda j: left[j] / rates[j])
                finish[i] = t
                left[i] = 0.0
                todo.discard(i)
                continue
            for i in active:
                left[i] -= rates[i] * (t_next - t)
            t = t_next
            for i in active:
                if left[i] <= done_eps[i]:
                    finish[i] = t
                    left[i] = 0.0
                    todo.discard(i)
    for i in todo:                     # right-censored at ``until``
        finish[i] = math.inf
    return finish, left


def shared_link_finish_times(jobs, capacity=math.inf):
    """Finish times of transfer jobs on a shared link (fluid max-min
    fair processor sharing) — the one-cohort view of ``fluid_schedule``.
    With infinite capacity this degenerates exactly to
    ``arrival + size / own_rate``."""
    return fluid_schedule(jobs, capacity)[0]


def retire_prefix(live, finishes, arrivals, now):
    """The shared retirement rule of the stateful resources
    (``FluidLink`` / the driver's server queue): among the ``live``
    ids, find the longest finish-sorted prefix whose finishes ALL
    predate both ``now`` (no future submission arrives earlier — the
    driver dispatches at arrivals >= its clock) and every kept id's
    arrival. Such a prefix can never have overlapped anything still
    schedulable, so dropping it leaves every kept schedule
    bit-identical. Returns (retired ids, kept ids). Under sustained
    overlap with no quiet point nothing retires — correctly, since
    everything still interacts through the shared resource."""
    order = sorted(live, key=lambda i: finishes[i])
    n = len(order)
    suffix_min = [math.inf] * (n + 1)
    for i in range(n - 1, -1, -1):
        suffix_min[i] = min(suffix_min[i + 1], arrivals[order[i]])
    cut = 0
    for i, f in enumerate(order):
        if finishes[f] > now:
            break
        if finishes[f] <= suffix_min[i + 1]:
            cut = i + 1
    return order[:cut], order[cut:]


class FluidLink:
    """A shared link that carries in-flight flows across dispatch
    cohorts (aggregation windows).

    Unlike a one-shot ``shared_link_finish_times`` call — which solves
    each cohort in isolation, so a straggler's transfer from an earlier
    window never slows the next window's — a ``FluidLink`` accumulates
    the flows submitted to it and ``solve()`` re-runs the max-min fair
    fluid schedule over all of them. Finish times of still-in-flight
    flows therefore shift *later* (never earlier: extra demand cannot
    speed anyone up) as new cohorts arrive, and the driver reconciles
    its pending events against the re-solve each round. Flows whose
    finish predates every later arrival recompute to bit-identical
    values, which is what keeps already-closed windows consistent — and
    is also what lets ``compact()`` retire them outright (finishes
    served from a cache afterwards), so the per-round re-solve cost is
    bounded by the flows still interacting rather than the full
    history.

    Flow arrivals may be revised via ``set_arrival`` while a flow is
    still pending (the pipelined driver does this for downlink flows,
    whose arrival is the commit event of a server-compute job that a
    re-solve may shift).
    """

    def __init__(self, capacity: float = math.inf):
        if capacity <= 0:
            raise ValueError(f"link capacity must be > 0: {capacity}")
        self.capacity = float(capacity)
        self._arrive: list = []
        self._bytes: list = []
        self._caps: list = []
        self._live: list = []          # fids still in the schedule
        self._finish_cache: dict = {}  # retired fid -> finish
        self.n_solves = 0              # fluid re-solve calls (telemetry)
        self.n_retired = 0             # flows retired by compact()
        self.abandoned_bytes = 0.0     # undelivered bytes of killed flows

    def __len__(self):
        return len(self._arrive)

    @property
    def contended(self) -> bool:
        return not math.isinf(self.capacity)

    @property
    def submitted_bytes(self) -> float:
        return sum(self._bytes)

    def submit(self, arrival: float, nbytes: float, rate: float) -> int:
        """Register a flow; returns its id (index into solve() output)."""
        if rate <= 0:
            raise ValueError(f"flow rate must be > 0: {rate}")
        self._arrive.append(float(arrival))
        self._bytes.append(float(nbytes))
        self._caps.append(float(rate))
        self._live.append(len(self._arrive) - 1)
        return len(self._arrive) - 1

    def set_arrival(self, fid: int, arrival: float):
        self._arrive[fid] = float(arrival)

    def abandon(self, fid: int, t: float) -> float:
        """Tear down flow ``fid`` at time ``t`` (its device died): bytes
        already drained stay drained, the undelivered remainder is
        dropped and metered under ``abandoned_bytes``. Returns the bytes
        abandoned.

        Truncating the flow's size to exactly what it had drained by
        ``t`` leaves every survivor's schedule before ``t`` unchanged
        (the active sets — and hence the max-min rates — are identical
        up to the instant the flow empties), makes the abandoned flow
        finish exactly at ``t``, and releases its capacity share from
        that instant on: survivors can only speed up. A flow that never
        started (arrival > t) is dropped whole and lands empty at its
        arrival, contending with nothing. Already-finished or retired
        flows are a no-op."""
        if fid in self._finish_cache:
            return 0.0                 # retired: fully drained long ago
        rem = self.remaining_at(t)[fid]
        if rem <= 0.0:
            return 0.0                 # delivered before the kill
        self._bytes[fid] -= rem
        self.abandoned_bytes += rem
        return rem

    def solve(self):
        """Finish times of ALL flows (retired ones from the cache),
        assuming no future arrivals."""
        self.n_solves += 1
        fins = [0.0] * len(self._arrive)
        for f, fin in self._finish_cache.items():
            fins[f] = fin
        jobs = [(self._arrive[f], self._bytes[f], self._caps[f])
                for f in self._live]
        for f, fin in zip(self._live,
                          fluid_schedule(jobs, self.capacity)[0]):
            fins[f] = fin
        return fins

    def remaining_at(self, t: float):
        """Per-flow bytes still in flight at time ``t`` (a flow that has
        not arrived yet reports its full size; a retired flow reports
        0.0, so after ``compact(now)`` this is exact for t >= now).
        Conservation — ``submitted_bytes == drained +
        sum(remaining_at(t))`` with the drain rate never exceeding the
        capacity — is property-tested in
        tests/test_driver_properties.py."""
        rem = [0.0] * len(self._arrive)
        jobs = [(self._arrive[f], self._bytes[f], self._caps[f])
                for f in self._live]
        for f, r in zip(self._live,
                        fluid_schedule(jobs, self.capacity, until=t)[1]):
            rem[f] = r
        return rem

    def compact(self, now: float):
        """Retire flows that can no longer influence any current or
        future schedule (see ``retire_prefix``); their finishes move to
        a cache that ``solve()`` keeps serving."""
        if len(self._live) <= 1:
            return
        fins = self.solve()
        retired, kept = retire_prefix(self._live, fins, self._arrive, now)
        if retired:
            for f in retired:
                self._finish_cache[f] = fins[f]
            self._live = kept
            self.n_retired += len(retired)

    def backlog_at(self, t: float):
        """(active flow count, bytes still in flight) at time ``t`` —
        the load the resource-aware forecast sees already draining on
        this link before the next cohort even dispatches. A flow counts
        as active when it has arrived and still holds bytes; flows that
        have not arrived yet are excluded (they are the future, not the
        backlog). Observational only (one right-censored solve)."""
        rem = self.remaining_at(t)
        active = [f for f in self._live
                  if self._arrive[f] <= t and rem[f] > 0.0]
        return len(active), sum(rem[f] for f in active)

    def utilization(self, t0: float, t1: float) -> float:
        """Fraction of the link capacity actually used over [t0, t1]:
        bytes drained by live flows in the interval over
        ``capacity * (t1 - t0)``. 0.0 on an uncontended (infinite-
        capacity) link or an empty interval. Observational only (two
        right-censored solves); retired flows report zero remaining at
        both ends and transferred nothing in any interval past their
        retirement, so the difference stays exact."""
        if t1 <= t0 or not self.contended:
            return 0.0
        drained = sum(self.remaining_at(t0)) - sum(self.remaining_at(t1))
        return max(0.0, drained) / (self.capacity * (t1 - t0))

    # ------------------------------------------------ checkpoint state
    def export_state(self) -> dict:
        """JSON-serializable snapshot of every flow (including retired
        history) — restoring it reproduces each subsequent solve()
        bit-exactly (Python floats round-trip exactly through repr-based
        JSON, and the fluid schedule is a deterministic function of the
        flow table)."""
        return {"capacity": self.capacity,
                "arrive": list(self._arrive),
                "bytes": list(self._bytes),
                "caps": list(self._caps),
                "live": list(self._live),
                "finish_cache": [[f, fin] for f, fin
                                 in sorted(self._finish_cache.items())],
                "n_solves": self.n_solves,
                "n_retired": self.n_retired,
                "abandoned_bytes": self.abandoned_bytes}

    @classmethod
    def from_state(cls, st: dict) -> "FluidLink":
        link = cls(st["capacity"])
        link._arrive = [float(x) for x in st["arrive"]]
        link._bytes = [float(x) for x in st["bytes"]]
        link._caps = [float(x) for x in st["caps"]]
        link._live = [int(f) for f in st["live"]]
        link._finish_cache = {int(f): float(fin)
                              for f, fin in st["finish_cache"]}
        link.n_solves = int(st["n_solves"])
        link.n_retired = int(st["n_retired"])
        link.abandoned_bytes = float(st["abandoned_bytes"])
        return link


# ---------------------------------------------------------------------------
# per-(device, round) latency draws
# ---------------------------------------------------------------------------
LATENCY_DISTS = ("constant", "uniform", "lognormal", "exp")


def _seed_int(cid) -> int:
    try:
        return int(cid)
    except (TypeError, ValueError):
        # stable across interpreter runs (built-in hash() is salted by
        # PYTHONHASHSEED and would break the replay guarantee)
        return zlib.crc32(str(cid).encode("utf-8"))


class LatencySampler:
    """Per-(device, round) message-latency draws.

    Every distribution is mean-preserving around ``base`` (turning a
    distribution on changes the spread of transport delay, not its
    average), and every draw is seeded by the ``(seed, cid, round)``
    triple — a fixed-seed replay reproduces each device-round's latency
    exactly, regardless of dispatch order or how many times the cost
    model re-prices the round.

      constant   always ``base`` (the seed regime — no RNG touched)
      uniform    base · U[1 − jitter, 1 + jitter]
      lognormal  base · exp(jitter · N(0,1) − jitter²/2)
      exp        base · Exp(1)  (jitter ignored)
    """

    def __init__(self, base: float = 0.0, dist: str = "constant",
                 jitter: float = 0.5, seed: int = 0):
        if dist not in LATENCY_DISTS:
            raise ValueError(f"unknown latency distribution {dist!r}; "
                             f"known: {LATENCY_DISTS}")
        if base < 0:
            raise ValueError(f"latency must be >= 0: {base}")
        if not 0.0 <= jitter <= 1.0:
            raise ValueError(f"latency jitter must be in [0, 1]: {jitter}")
        self.base = float(base)
        self.dist = dist
        self.jitter = float(jitter)
        self.seed = int(seed)

    @property
    def mean(self) -> float:
        return self.base

    def sample(self, cid, rnd: int = 0) -> float:
        if self.dist == "constant" or self.base == 0.0:
            return self.base
        rng = np.random.default_rng(
            (self.seed, _seed_int(cid), int(rnd)))
        if self.dist == "uniform":
            j = self.jitter
            return self.base * (1.0 - j + 2.0 * j * float(rng.random()))
        if self.dist == "lognormal":
            s = self.jitter
            return self.base * math.exp(
                s * float(rng.standard_normal()) - 0.5 * s * s)
        return self.base * float(rng.exponential(1.0))


def get_link(name: str = "static", **kw):
    if name == "static":
        return StaticLink()
    if name == "trace":
        return LinkTrace(**kw)
    raise KeyError(f"unknown link model {name!r}; known: static, trace")
