"""Step builders + abstract input specs for every (arch × input shape).

Shapes (assigned):
  train_4k     seq 4,096   global_batch 256   -> fused S²FL round step
  prefill_32k  seq 32,768  global_batch 32    -> prefill (cache build)
  decode_32k   seq 32,768  global_batch 128   -> one-token serve step
  long_500k    seq 524,288 global_batch 1     -> one-token serve step
                                                 (sub-quadratic archs only)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.round_step import make_s2fl_train_step, train_step_shardings
from repro.models import transformer as tf_mod
from repro.models.frontends import frontend_embed_spec
from repro.models.sharding import (batch_spec, cache_specs, data_axes,
                                   model_param_specs)

SHAPES = {
    "train_4k": {"seq": 4096, "batch": 256, "kind": "train"},
    "prefill_32k": {"seq": 32768, "batch": 32, "kind": "prefill"},
    "decode_32k": {"seq": 32768, "batch": 128, "kind": "decode"},
    "long_500k": {"seq": 524288, "batch": 1, "kind": "decode"},
}

# S²FL defaults at pod scale: 16 cohorts (one per data shard), 4 balance
# groups, one of the plan's split points.
DEFAULT_GROUPS = 4


def long_context_ok(cfg) -> bool:
    """long_500k runs for SSM/hybrid and sliding-window dense archs; pure
    full-attention archs are skipped (DESIGN.md §4)."""
    return cfg.arch_type in ("ssm", "hybrid") or cfg.sliding_window > 0


def shape_applicable(cfg, shape: str) -> bool:
    if shape == "long_500k":
        return long_context_ok(cfg)
    return True


def default_split(cfg) -> int:
    from repro.core.split import default_plan
    return default_plan(cfg.n_layers).split_points[-1]


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------
def train_inputs(cfg, *, batch: int, seq: int):
    i32 = jnp.int32
    specs = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), i32),
        "labels": jax.ShapeDtypeStruct((batch, seq), i32),
        "perm": jax.ShapeDtypeStruct((batch,), i32),
    }
    if cfg.frontend:
        specs["prefix"] = frontend_embed_spec(cfg, batch)
    return specs


def prefill_inputs(cfg, *, batch: int, seq: int):
    specs = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    if cfg.frontend:
        specs["prefix"] = frontend_embed_spec(cfg, batch)
    return specs


def decode_inputs(cfg, *, batch: int, seq: int):
    caches = jax.eval_shape(
        functools.partial(tf_mod.init_caches, cfg, batch, seq))
    return {
        "token": jax.ShapeDtypeStruct((batch, 1), jnp.int32),
        "index": jax.ShapeDtypeStruct((), jnp.int32),
        "caches": caches,
    }


def input_specs(cfg, shape: str):
    s = SHAPES[shape]
    fn = {"train": train_inputs, "prefill": prefill_inputs,
          "decode": decode_inputs}[s["kind"]]
    return fn(cfg, batch=s["batch"], seq=s["seq"])


# ---------------------------------------------------------------------------
# step builders (returns (fn, in_shardings, out_shardings, abstract_args))
# ---------------------------------------------------------------------------
def abstract_model_params(cfg, mesh):
    from repro.models.transformer import abstract_model
    return abstract_model(cfg)


def build_train_step(cfg, mesh, *, split=None, n_groups: int = DEFAULT_GROUPS,
                     lr: float = 0.01, shape: str = "train_4k",
                     remat: bool = True, scan_layers=None,
                     remat_policy=None):
    """scan_layers None -> use the config's flag. Scan keeps compile time
    O(#block kinds) (mandatory for kimi-k2), but XLA's cost_analysis
    counts while-loop bodies ONCE — the dry-run corrects flops by
    two-point depth extrapolation for scanned configs (dryrun.py)."""
    import dataclasses
    repl = {}
    if remat and not cfg.remat:
        repl["remat"] = True
    if scan_layers is not None and scan_layers != cfg.scan_layers:
        repl["scan_layers"] = scan_layers
    if remat_policy is not None:
        repl["remat_policy"] = remat_policy
    if cfg.n_experts and not cfg.moe_dispatch_shards:
        axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        nsh = 1
        for a in data_axes(mesh):
            nsh *= axis_sizes[a]
        repl["moe_dispatch_shards"] = nsh   # shard-local dispatch (moe.py)
        repl["moe_dispatch_axes"] = tuple(data_axes(mesh))
    if repl:
        cfg = dataclasses.replace(cfg, **repl)
    split = split if split is not None else default_split(cfg)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_cohorts = 1
    for a in data_axes(mesh):
        n_cohorts *= axis_sizes[a]
    step = make_s2fl_train_step(cfg, split, n_groups, lr,
                                dp_axes=data_axes(mesh),
                                group_members=max(1, n_cohorts // n_groups))
    batch_abs = input_specs(cfg, shape)
    in_sh, out_sh = train_step_shardings(cfg, mesh, batch_abs)
    params_abs = abstract_model_params(cfg, mesh)
    return step, in_sh, out_sh, (params_abs, batch_abs)


def build_prefill_step(cfg, mesh, *, shape: str = "prefill_32k",
                       max_len=None):
    s = SHAPES[shape]
    batch, seq = s["batch"], s["seq"]
    # modality prefix tokens occupy cache slots too
    max_len = max_len or (seq + (cfg.n_frontend_tokens if cfg.frontend
                                 else 0))

    def step(params, batch_in):
        logits, caches, n = tf_mod.prefill(cfg, params, batch_in["tokens"],
                                           max_len,
                                           batch_in.get("prefix"))
        return logits, caches

    batch_abs = input_specs(cfg, shape)
    pspecs = model_param_specs(cfg, mesh)
    to_sh = lambda t: jax.tree.map(
        lambda sp: NamedSharding(mesh, sp), t,
        is_leaf=lambda x: isinstance(x, P))
    bspec = {k: NamedSharding(mesh, batch_spec(mesh, v.ndim,
                                               batch_size=v.shape[0]))
             for k, v in batch_abs.items()}
    caches_abs = jax.eval_shape(
        functools.partial(tf_mod.init_caches, cfg, batch, max_len))
    cspecs = cache_specs(cfg, mesh, caches_abs, batch)
    out_sh = (NamedSharding(mesh, batch_spec(mesh, 3, batch_size=batch)),
              to_sh(cspecs))
    params_abs = abstract_model_params(cfg, mesh)
    return step, (to_sh(pspecs), bspec), out_sh, (params_abs, batch_abs)


def build_decode_step(cfg, mesh, *, shape: str = "decode_32k"):
    s = SHAPES[shape]
    batch, seq = s["batch"], s["seq"]

    def step(params, batch_in):
        logits, caches = tf_mod.decode_step(cfg, params, batch_in["token"],
                                            batch_in["caches"],
                                            batch_in["index"])
        return logits, caches

    batch_abs = input_specs(cfg, shape)
    pspecs = model_param_specs(cfg, mesh)
    to_sh = lambda t: jax.tree.map(
        lambda sp: NamedSharding(mesh, sp), t,
        is_leaf=lambda x: isinstance(x, P))
    cspecs = cache_specs(cfg, mesh, batch_abs["caches"], batch)
    bspec = {
        "token": NamedSharding(mesh, batch_spec(mesh, 2, batch_size=batch)),
        "index": NamedSharding(mesh, P()),
        "caches": to_sh(cspecs),
    }
    out_sh = (NamedSharding(mesh, batch_spec(mesh, 3, batch_size=batch)),
              to_sh(cspecs))
    params_abs = abstract_model_params(cfg, mesh)
    return step, (to_sh(pspecs), bspec), out_sh, (params_abs, batch_abs)


def build_step(cfg, mesh, shape: str, **kw):
    kind = SHAPES[shape]["kind"]
    if kind == "train":
        return build_train_step(cfg, mesh, shape=shape, **kw)
    if kind == "prefill":
        return build_prefill_step(cfg, mesh, shape=shape, **kw)
    return build_decode_step(cfg, mesh, shape=shape, **kw)
