"""End-to-end S²FL training driver (runs for real — CPU-scale configs —
and doubles as the pod-scale launcher skeleton).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch resnet8 \
      --mode s2fl --rounds 50 --alpha 0.5 [--reduced]
  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
      --reduced --rounds 30 --mode s2fl

Restartable service loop (README §Service loop): ``--checkpoint-every N``
snapshots the FULL training state (model + driver timeline + channel +
scheduler + rng — checkpoint/state.py) every N rounds into
``--checkpoint-dir``; a crashed run resumes with ``--resume-from
<snapshot.npz>`` and replays the remaining rounds bit-exactly on the
fp32 sync path. ``--fault-plan`` / ``--fault-kill-prob`` arm churn
injection (core/faults.py) for chaos drills against the same loop.
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro.configs import get_config, make_reduced
from repro.configs.base import CommConfig, DriverConfig
from repro.core.engine import EngineConfig, S2FLEngine
from repro.data.partition import federate
from repro.data.synthetic import make_image_dataset, make_lm_dataset
from repro.models import SplitModel


def build_data(cfg, *, n_train: int, n_test: int, n_clients: int, alpha,
               seq_len: int, seed: int = 0):
    if getattr(cfg, "arch_type", "") == "cnn" or hasattr(cfg, "family"):
        train = make_image_dataset(n_train, n_classes=cfg.n_classes,
                                   image_size=cfg.image_size, seed=seed)
        test = make_image_dataset(n_test, n_classes=cfg.n_classes,
                                  image_size=cfg.image_size, seed=seed + 1)
        n_classes = cfg.n_classes
    else:
        vocab = min(cfg.vocab_size, 256)
        train = make_lm_dataset(n_train, seq_len=seq_len, vocab=vocab,
                                seed=seed)
        test = make_lm_dataset(n_test, seq_len=seq_len, vocab=vocab,
                               seed=seed + 1)
        n_classes = 10
    fed = federate(train, n_clients, alpha=alpha, seed=seed)
    return fed, test, n_classes


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="resnet8")
    ap.add_argument("--mode", default="s2fl",
                    choices=["s2fl", "sfl", "fedavg"])
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--per-round", type=int, default=5)
    ap.add_argument("--alpha", type=float, default=None,
                    help="Dirichlet alpha; omit for IID")
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--n-train", type=int, default=4000)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced model variant (CPU-friendly)")
    ap.add_argument("--no-balance", action="store_true")
    ap.add_argument("--no-sliding", action="store_true")
    ap.add_argument("--eval-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    ap.add_argument("--history-out", default=None,
                    help="dump engine.history (per-round records) as "
                         "JSON to this path")
    # observability (repro.observe) — see core/README.md §Observability
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome trace-event JSON of the run "
                         "(open in https://ui.perfetto.dev); also "
                         "embeds the full recorder dump for "
                         "benchmarks/trace_report.py")
    ap.add_argument("--metrics-out", default=None,
                    help="stream one JSON line per emission (round "
                         "record + live metrics snapshot) to this "
                         "path — the long-running-service feed")
    ap.add_argument("--metrics-every", type=int, default=1,
                    help="emit a metrics line every N rounds "
                         "(with --metrics-out)")
    # transport (repro.comm)
    codecs = ["fp32", "bf16", "fp16", "int8", "topk", "randk"]
    ap.add_argument("--codec", "--uplink-codec", dest="codec",
                    default="fp32", choices=codecs,
                    help="uplink feature codec")
    ap.add_argument("--grad-codec", "--downlink-codec", dest="grad_codec",
                    default="", choices=[""] + codecs,
                    help="downlink dfx codec (default: same as --codec)")
    ap.add_argument("--dispatch-codec", default="fp32", choices=codecs,
                    help="model-leg codec: Wc dispatch/collect (and the "
                         "FedAvg broadcast + QSGD-style update upload); "
                         "fp32 = the seed's uncompressed legs")
    ap.add_argument("--error-feedback", action="store_true",
                    help="per-(device, tensor) residual accumulators: "
                         "compression error is added back before the "
                         "next round's encode")
    ap.add_argument("--topk-frac", type=float, default=0.1,
                    help="kept fraction for the topk/randk sparsifiers")
    ap.add_argument("--link-trace", default="",
                    help="JSON LinkTrace file (default: static Table-1)")
    ap.add_argument("--latency", type=float, default=0.0,
                    help="per-message link latency in seconds (four "
                         "messages per device-round)")
    ap.add_argument("--latency-dist", default="constant",
                    choices=["constant", "uniform", "lognormal", "exp"],
                    help="per-(device, round) latency distribution "
                         "around the --latency mean (deterministic "
                         "draw per device-round)")
    ap.add_argument("--latency-jitter", type=float, default=0.5,
                    help="spread of the non-constant latency "
                         "distributions (uniform half-width / "
                         "lognormal sigma, as a fraction of the mean)")
    ap.add_argument("--latency-seed", type=int, default=0,
                    help="seed of the latency draw stream")
    ap.add_argument("--contention", type=float, default=0.0,
                    help="shared Main-Server uplink capacity in Table-1 "
                         "elements/s (0 = uncontended); concurrent "
                         "uploads contend for it under --pipeline")
    ap.add_argument("--downlink-contention", type=float, default=0.0,
                    help="shared Main-Server downlink (egress) capacity "
                         "in Table-1 elements/s (0 = uncontended); "
                         "concurrent dfx downloads contend for it "
                         "under --pipeline")
    # round loop (repro.core.driver)
    ap.add_argument("--exec-mode", default="sync",
                    choices=["sync", "semi_async"],
                    help="round clock: Eq.-1 barrier vs event-queue "
                         "straggler overlap")
    ap.add_argument("--staleness-cap", type=int, default=1,
                    help="semi_async: max rounds an update may lag "
                         "(0 degenerates to sync)")
    ap.add_argument("--quorum", type=float, default=0.5,
                    help="semi_async: arrival fraction that closes the "
                         "aggregation window")
    ap.add_argument("--predictive", action="store_true",
                    help="sliding scheduler forecasts the link rate at "
                         "the projected completion time")
    ap.add_argument("--pipeline", action="store_true",
                    help="phase-level event pipeline: upload / server "
                         "compute / download phases overlap across "
                         "devices and groups")
    ap.add_argument("--server-slots", type=int, default=0,
                    help="max concurrent group backwards on the Main "
                         "Server GPU (FIFO queue; 0 = unbounded); only "
                         "observable under --pipeline")
    ap.add_argument("--fused-comm", action="store_true",
                    help="flush each direction's whole cohort through "
                         "one fused jitted call (comm/fused.py): bytes "
                         "metered bit-equal to the sequential path, "
                         "tensors within 1e-6")
    ap.add_argument("--fused-server", action="store_true",
                    help="stack same-signature concurrent groups' "
                         "server backwards into one vmapped, donated "
                         "step (numerics may drift ~1e-4)")
    ap.add_argument("--gate-redispatch", action="store_true",
                    help="a device waits out its own draining download "
                         "before its next upload may start (off = the "
                         "semi-async queue's overcommit optimism); "
                         "only observable under --pipeline")
    # resource-aware control plane (core/control.py) — see
    # core/README.md §Control plane
    ap.add_argument("--resource-aware", action="store_true",
                    help="price candidate splits against live driver "
                         "state (server queue depth, fluid-link "
                         "backlogs, draining flows, learned horizon "
                         "band) instead of the link model's mean rate")
    ap.add_argument("--scheduler", default="median",
                    choices=["median", "mintime", "joint"],
                    help="split policy: paper median matching, "
                         "per-device mintime, or joint split x batch-"
                         "fraction tuning (joint needs "
                         "--resource-aware to price fractions)")
    ap.add_argument("--batch-fracs", default="",
                    help="comma list of candidate batch fractions for "
                         "--scheduler joint (default 1.0,0.75,0.5)")
    ap.add_argument("--auto-knobs", action="store_true",
                    help="probe nearby (quorum, staleness_cap) pairs "
                         "and lock the fastest (semi-async only)")
    # batched million-device fleets (core/fleet.py) — see
    # core/README.md §Fleet scale
    ap.add_argument("--fleet-size", type=int, default=0,
                    help="simulate this many devices as batched (P,) "
                         "population tables: cohorts are fleet-sampled "
                         "each round and Device objects materialize "
                         "only for sampled cids (0 = the object grid "
                         "sized by --clients)")
    ap.add_argument("--clusters", type=int, default=0,
                    help="edge clusters for hierarchical aggregation "
                         "(devices -> clusters -> main server); <= 1 "
                         "keeps the flat aggregation window")
    ap.add_argument("--cluster-quorum", type=float, default=1.0,
                    help="per-cluster close quantile: each cluster "
                         "closes at this fraction of its members' "
                         "arrivals, then --quorum applies over the "
                         "cluster close times")
    # fault injection + restartable service loop (core/faults.py,
    # checkpoint/state.py) — see core/README.md §Failure semantics
    ap.add_argument("--fault-plan", default="",
                    help="JSON FaultPlan file of seeded kill/rejoin "
                         "events (core/faults.py to_file format)")
    ap.add_argument("--fault-kill-prob", type=float, default=0.0,
                    help="random-process churn: per-round kill "
                         "probability per alive device (> 0 generates "
                         "a seeded FaultPlan; ignored with "
                         "--fault-plan)")
    ap.add_argument("--fault-rejoin-prob", type=float, default=0.5,
                    help="per-round rejoin probability per dead device "
                         "(random-process churn)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed of the random fault process")
    ap.add_argument("--fault-server-policy", default="cancel",
                    choices=["cancel", "orphan"],
                    help="a dead device's server job: 'cancel' frees "
                         "the slot at the kill instant, 'orphan' lets "
                         "an already-fed backward run to completion "
                         "(result dropped either way)")
    ap.add_argument("--fault-residual-policy", default="restore",
                    choices=["restore", "discard"],
                    help="a rejoining device's quarantined "
                         "error-feedback residuals: restored, or "
                         "discarded with their L2 mass metered")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="snapshot the FULL training state every N "
                         "rounds into --checkpoint-dir (0 = off)")
    ap.add_argument("--checkpoint-dir", default="checkpoints",
                    help="where --checkpoint-every writes "
                         "round<NNNNN>.npz snapshots")
    ap.add_argument("--resume-from", default="",
                    help="resume a crashed/stopped run from a "
                         "checkpoint/state.py snapshot; the remaining "
                         "rounds replay bit-exactly on the fp32 sync "
                         "path")
    return ap


def main(argv=None):
    args = build_parser().parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced and not hasattr(cfg, "family"):
        cfg = make_reduced(cfg)
    model = SplitModel(cfg)
    fed, test, n_classes = build_data(
        cfg, n_train=args.n_train, n_test=max(500, args.n_train // 8),
        n_clients=args.clients, alpha=args.alpha, seq_len=args.seq_len,
        seed=args.seed)

    ccfg = CommConfig(codec=args.codec, grad_codec=args.grad_codec,
                      dispatch_codec=args.dispatch_codec,
                      error_feedback=args.error_feedback,
                      topk_frac=args.topk_frac,
                      link="trace" if args.link_trace else "static",
                      trace_file=args.link_trace, latency=args.latency,
                      latency_dist=args.latency_dist,
                      latency_jitter=args.latency_jitter,
                      latency_seed=args.latency_seed,
                      uplink_capacity=args.contention,
                      downlink_capacity=args.downlink_contention)
    dcfg = DriverConfig(exec_mode=args.exec_mode,
                        staleness_cap=args.staleness_cap,
                        quorum=args.quorum, predictive=args.predictive,
                        pipeline=args.pipeline,
                        server_concurrency=args.server_slots,
                        gate_redispatch=args.gate_redispatch,
                        resource_aware=args.resource_aware,
                        auto_knobs=args.auto_knobs,
                        fleet_size=args.fleet_size,
                        clusters=args.clusters,
                        cluster_quorum=args.cluster_quorum)
    fracs = tuple(float(f) for f in args.batch_fracs.split(",")
                  if f.strip()) if args.batch_fracs else ()
    ecfg = EngineConfig(
        mode=args.mode, rounds=args.rounds,
        clients_per_round=args.per_round, batch_size=args.batch_size,
        local_steps=args.local_steps, lr=args.lr, seed=args.seed,
        use_balance=not args.no_balance, use_sliding=not args.no_sliding,
        scheduler=args.scheduler, batch_fracs=fracs,
        n_classes=n_classes, comm=ccfg, driver=dcfg,
        fused_comm=args.fused_comm, fused_server=args.fused_server)

    # churn: an explicit plan file wins; otherwise a seeded random
    # process over the federation's cids (deterministic per seed, so a
    # resumed run sees the identical schedule)
    fault_plan = None
    if args.fault_plan:
        from repro.core.faults import FaultPlan
        fault_plan = FaultPlan.from_file(args.fault_plan)
    elif args.fault_kill_prob > 0:
        from repro.core.faults import FaultPlan
        fault_plan = FaultPlan.random(
            sorted(fed), args.rounds, seed=args.fault_seed,
            kill_prob=args.fault_kill_prob,
            rejoin_prob=args.fault_rejoin_prob,
            server_policy=args.fault_server_policy,
            residual_policy=args.fault_residual_policy)

    # observability: one recorder feeds the driver's flight/window
    # hooks, the channel's wire counters, and (when streaming) the live
    # metrics registry — absent flags, nothing is built and every hook
    # stays a dead branch
    recorder, registry, sink = None, None, None
    if args.trace_out or args.metrics_out:
        from repro.observe import JsonlSink, MetricsRegistry, Recorder
        registry = MetricsRegistry() if args.metrics_out else None
        recorder = Recorder(metrics=registry)
        if args.metrics_out:
            sink = JsonlSink(args.metrics_out)

    eng = S2FLEngine(model, fed, ecfg, recorder=recorder,
                     fault_plan=fault_plan)

    # service loop: resume restores the FULL state (history included —
    # its length is the next round index) and replays the remainder
    start_round = 0
    if args.resume_from:
        from repro.checkpoint import restore_run_state
        restore_run_state(args.resume_from, eng)
        start_round = len(eng.history)
        print(f"== resumed {args.resume_from} at round {start_round} ==")

    emitted = 0

    def on_round(rec):
        nonlocal emitted
        if sink is not None \
                and rec["round"] % max(args.metrics_every, 1) == 0:
            sink.emit({"kind": "round", **rec,
                       "metrics": registry.snapshot()})
            emitted += 1
        done = rec["round"] + 1
        if args.checkpoint_every and done % args.checkpoint_every == 0:
            from repro.checkpoint import save_run_state
            os.makedirs(args.checkpoint_dir, exist_ok=True)
            path = os.path.join(args.checkpoint_dir,
                                f"round{done:05d}.npz")
            save_run_state(path, eng)
            print(f"  checkpoint   {path}")

    t0 = time.time()
    eng.run(rounds=max(args.rounds - start_round, 0), eval_data=test,
            eval_every=args.eval_every, verbose=True, on_round=on_round)
    final = eng.evaluate(test)
    wall = time.time() - t0

    summary = {
        "mode": args.mode, "arch": args.arch, "rounds": args.rounds,
        "clients": args.clients, "per_round": args.per_round,
        "final_loss": final["loss"], "final_acc": final["acc"],
        "sim_clock_s": eng.clock, "comm_bytes": eng.comm,
        "dispatched": eng.driver.n_dispatched,
        "committed": eng.driver.n_committed,
        "abandoned": eng.driver.n_abandoned,
        "wall_s": wall,
    }
    print("== run summary ==")
    for k, v in summary.items():
        if isinstance(v, float):
            print(f"  {k:<12} {v:.6g}")
        else:
            print(f"  {k:<12} {v}")

    if sink is not None:
        sink.emit({"kind": "summary", **summary,
                   "metrics": registry.snapshot()})
        sink.close()
        print(f"  metrics      {args.metrics_out} "
              f"({emitted + 1} records)")
    if args.trace_out:
        from repro.observe import summarize, write_chrome_trace
        write_chrome_trace(recorder, args.trace_out)
        crit = summarize(recorder)
        print(f"  trace        {args.trace_out} "
              f"({len(recorder.flights)} flights, "
              f"{crit['windows']} windows, "
              f"top straggler {crit['top_straggler']})")
    if args.history_out:
        with open(args.history_out, "w") as f:
            json.dump(eng.history, f, indent=1)
        print(f"  history      {args.history_out}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"history": eng.history, "final": final,
                       "clock": eng.clock, "comm": eng.comm,
                       "summary": summary}, f, indent=1)


if __name__ == "__main__":
    main()
