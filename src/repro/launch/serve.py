"""Batched serving driver: prefill a batch of prompts, decode greedily.

CPU-scale by default (--reduced); at pod scale the same step functions are
what the dry-run lowers (build_prefill_step / build_decode_step).

  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
      --reduced --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, make_reduced
from repro.models import SplitModel
from repro.models import transformer as tf_mod
from repro.models.frontends import synth_frontend_embeds


def generate(cfg, params, tokens, *, steps: int, prefix=None,
             temperature: float = 0.0, seed: int = 0):
    """Greedy/temperature decode. Returns (B, steps) generated tokens."""
    B, S = tokens.shape
    max_len = S + steps + (cfg.n_frontend_tokens if cfg.frontend else 0)
    logits, caches, n_pre = tf_mod.prefill(cfg, params, tokens, max_len,
                                           prefix)
    decode = jax.jit(lambda p, t, c, i: tf_mod.decode_step(cfg, p, t, c, i))
    key = jax.random.PRNGKey(seed)
    out = []
    tok = None
    for t in range(steps):
        lg = logits[:, -1, :cfg.vocab_size]
        if temperature > 0:
            key, k = jax.random.split(key)
            tok = jax.random.categorical(k, lg / temperature)[:, None]
        else:
            tok = jnp.argmax(lg, axis=-1)[:, None]
        out.append(tok)
        pos = jnp.asarray(n_pre + t, jnp.int32)
        logits, caches = decode(params, tok.astype(jnp.int32), caches, pos)
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        from repro.configs import make_reduced
        cfg = make_reduced(cfg)
    model = SplitModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    prefix = (synth_frontend_embeds(cfg, key, args.batch)
              if cfg.frontend else None)
    t0 = time.time()
    gen = generate(cfg, params, tokens, steps=args.gen, prefix=prefix,
                   temperature=args.temperature)
    dt = time.time() - t0
    print("generated:", gen[:2])
    print(f"{args.batch}x{args.gen} tokens in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
