import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import (device count locks on first init).

"""Multi-pod dry-run: lower + compile every (arch × input shape) on the
production mesh and report memory / cost / collective analysis.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-1.8b \
      --shape train_4k [--multi-pod] [--all] [--json out.json]
"""
import argparse
import json
import sys
import time

import jax

from repro.configs import get_config, list_configs
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import SHAPES, build_step, shape_applicable
from repro.utils import hlo as hlo_util
from repro.utils.flops import model_flops_6nd


def _scan_corrected_cost(cfg, mesh, shape, step_kw):
    """XLA cost_analysis counts while-loop bodies ONCE (verified with a
    controlled scan-of-matmuls), so scanned configs under-report flops /
    bytes / collectives by the trip count. Correction: lower two reduced-
    depth UNROLLED variants at full tensor shapes and extrapolate the
    per-layer marginal cost linearly to the full depth. Marginal layers
    sit server-side of the split (split=1) — the same math at the same
    shapes, so the linear model is exact up to embed/head constants."""
    import dataclasses

    from repro.utils.hlo import collective_bytes as coll_fn

    def make(L):
        return dataclasses.replace(
            cfg, n_layers=L, block_pattern=cfg.block_pattern[:L],
            ffn_pattern=cfg.ffn_pattern[:L], scan_layers=False)

    from repro.models.transformer import _segments
    segs = _segments(cfg, 0, cfg.n_layers)
    prefix = max(segs, key=lambda s: s[1] - s[0])[0]
    L1, L2 = prefix + 2, prefix + 4
    pts = []
    for L in (L1, L2):
        c = make(L)
        kw = dict(step_kw)
        if SHAPES[shape]["kind"] == "train":
            kw["split"] = min(kw.get("split") or 1, 1) or 1
        step, in_sh, out_sh, (pa, ba) = build_step(c, mesh, shape, **kw)
        with mesh:
            compiled = jax.jit(step, in_shardings=in_sh,
                               out_shardings=out_sh).lower(pa, ba).compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        pts.append((float(cost.get("flops", 0.0)),
                    float(cost.get("bytes accessed", 0.0)),
                    float(coll_fn(compiled.as_text())["_total"])))
    dL = L2 - L1
    out = []
    for i in range(3):
        slope = (pts[1][i] - pts[0][i]) / dL
        out.append(pts[0][i] + slope * (cfg.n_layers - L1))
    return tuple(out)          # (flops, bytes, coll_bytes) per chip


def dryrun_one(arch: str, shape: str, *, multi_pod: bool = False,
               verbose: bool = True, **step_kw):
    cfg = get_config(arch)
    if not shape_applicable(cfg, shape):
        return {"arch": arch, "shape": shape, "skipped": True,
                "reason": "long-context not applicable (full attention)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    step, in_sh, out_sh, (params_abs, batch_abs) = build_step(
        cfg, mesh, shape, **step_kw)
    donate = (0,) if SHAPES[shape]["kind"] == "train" else ()
    if SHAPES[shape]["kind"] == "decode":
        donate = (1,)                       # decode donates the caches
    with mesh:
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(params_abs, batch_abs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    s = SHAPES[shape]
    n_tokens = s["batch"] * (s["seq"] if s["kind"] != "decode" else 1)
    mf = model_flops_6nd(cfg, n_tokens)
    if s["kind"] != "train":
        mf /= 3.0                                  # fwd only (no bwd)
    roof = hlo_util.analyze(compiled, arch=arch, shape=shape,
                            n_chips=n_chips, model_flops=mf)
    estimated = False
    if cfg.scan_layers and s["kind"] == "train":
        # while-loop bodies are cost-counted once; extrapolate (see above)
        fl, by, cb = _scan_corrected_cost(cfg, mesh, shape, step_kw)
        roof.hlo_flops, roof.hlo_bytes, roof.coll_bytes = fl, by, cb
        estimated = True
    rec = roof.row()
    rec["flops_estimated"] = estimated
    rec.update({
        "multi_pod": multi_pod,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        "coll_counts": roof.coll_detail.get("_counts"),
    })
    if verbose:
        print(f"== {arch} × {shape} ({'multi' if multi_pod else 'single'}"
              f"-pod, {n_chips} chips) ==")
        print("memory_analysis:", mem)
        print("cost_analysis: flops=%.3e bytes=%.3e" %
              (rec["hlo_flops"], rec["hlo_bytes"]))
        print("collectives:", rec["coll_counts"],
              "bytes=%.3e" % rec["coll_bytes"])
        print("roofline: compute=%.4fs memory=%.4fs collective=%.4fs "
              "dominant=%s useful=%.2f" %
              (rec["t_compute_s"], rec["t_memory_s"], rec["t_collective_s"],
               rec["dominant"], rec["useful_ratio"]))
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="all (arch × shape) pairs")
    ap.add_argument("--split", type=int, default=None)
    ap.add_argument("--groups", type=int, default=None)
    ap.add_argument("--remat-policy", default=None, choices=["dots"],
                    help="selective remat (train shapes)")
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    lm_archs = [a for a in list_configs()
                if getattr(get_config(a), "arch_type", "cnn") != "cnn"]
    pairs = []
    if args.all:
        pairs = [(a, s) for a in lm_archs for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        pairs = [(args.arch, args.shape)]

    kw = {}
    if args.split is not None:
        kw["split"] = args.split
    if args.groups is not None:
        kw["n_groups"] = args.groups
    if args.remat_policy is not None:
        kw["remat_policy"] = args.remat_policy

    out = []
    for arch, shape in pairs:
        skw = dict(kw) if SHAPES[shape]["kind"] == "train" else {}
        try:
            rec = dryrun_one(arch, shape, multi_pod=args.multi_pod, **skw)
        except Exception as e:                       # noqa: BLE001
            rec = {"arch": arch, "shape": shape, "error": repr(e)[:500]}
            print(f"!! {arch} × {shape} FAILED: {rec['error']}",
                  file=sys.stderr)
        out.append(rec)
        if args.json:                    # incremental: crash-safe
            with open(args.json, "w") as f:
                json.dump(out, f, indent=1, default=str)
    n_err = sum(1 for r in out if "error" in r)
    print(f"\n{len(out)} pairs, {n_err} errors")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
