"""Observability for the event pipeline: flight-level tracing
(``trace``), streaming metrics (``metrics``), Chrome trace-event export
(``export``) and per-window critical-path attribution (``critical``).

Everything is dependency-injected and default-off: build a
``Recorder``, pass it to ``RoundDriver``/``S2FLEngine`` (and set it on
the ``CommChannel``), and the driver's hooks populate it; without one
the hooks are dead branches and the simulated timeline is bit-exact
with the un-instrumented seed (golden-tested).
"""
from repro.observe.critical import (summarize, verify_reconstruction,
                                    window_breakdown)
from repro.observe.export import (chrome_trace, load_recorder,
                                  write_chrome_trace)
from repro.observe.metrics import Histogram, JsonlSink, MetricsRegistry
from repro.observe.trace import NullRecorder, Recorder, TraceRecorder

__all__ = [
    "TraceRecorder", "NullRecorder", "Recorder",
    "MetricsRegistry", "JsonlSink", "Histogram",
    "chrome_trace", "write_chrome_trace", "load_recorder",
    "window_breakdown", "summarize", "verify_reconstruction",
]
