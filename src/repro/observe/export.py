"""Chrome trace-event export — load the file in Perfetto
(https://ui.perfetto.dev) or ``chrome://tracing`` and see the pipelined
round: one track per device (client pre/post compute), one lane-packed
track group each for the shared uplink, the server slots, and the shared
downlink, window spans on a timeline track, and counter tracks for every
gauge the recorder sampled.

The format is the JSON Object Format of the Trace Event spec:
``{"traceEvents": [...]}`` with "X" (complete) events carrying ``ts`` /
``dur`` in microseconds and "M" (metadata) events naming processes and
threads. Extra top-level keys are explicitly allowed, so the full
recorder dump rides along under ``"s2fl"`` — one artifact is both
human-viewable and machine-readable (``benchmarks/trace_report.py``
reads it back via ``Recorder.from_json``).
"""
from __future__ import annotations

import json
import math

# process ids = track groups (Perfetto renders one group per pid)
PID_TIMELINE = 0
PID_DEVICES = 1
PID_UPLINK = 2
PID_SERVER = 3
PID_DOWNLINK = 4

_US = 1e6          # simulated seconds -> trace microseconds


def _lanes(spans):
    """Greedy lane assignment for overlapping [start, end) spans:
    each span takes the lowest lane that is free at its start. Returns
    the spans' lane indices (in input order)."""
    order = sorted(range(len(spans)), key=lambda i: spans[i][0])
    free: list = []            # lane -> last end
    out = [0] * len(spans)
    for i in order:
        s, e = spans[i]
        for lane, busy_until in enumerate(free):
            if busy_until <= s + 1e-12:
                free[lane] = e
                out[i] = lane
                break
        else:
            out[i] = len(free)
            free.append(e)
    return out


def _x(name, pid, tid, t0, t1, args=None):
    ev = {"name": name, "ph": "X", "pid": pid, "tid": tid,
          "ts": t0 * _US, "dur": max(t1 - t0, 0.0) * _US, "cat": "s2fl"}
    if args:
        ev["args"] = args
    return ev


def _meta(pid, tid, what, name):
    return {"name": what, "ph": "M", "pid": pid, "tid": tid,
            "args": {"name": name}}


def _fin(*xs):
    return all(isinstance(x, (int, float)) and math.isfinite(x)
               for x in xs)


def chrome_trace(rec) -> dict:
    """Recorder -> Trace Event JSON object (Perfetto-loadable)."""
    events = [
        _meta(PID_TIMELINE, 0, "process_name", "timeline"),
        _meta(PID_DEVICES, 0, "process_name", "devices"),
        _meta(PID_UPLINK, 0, "process_name", "uplink"),
        _meta(PID_SERVER, 0, "process_name", "server"),
        _meta(PID_DOWNLINK, 0, "process_name", "downlink"),
        _meta(PID_TIMELINE, 0, "thread_name", "aggregation windows"),
    ]

    # -- aggregation windows on the timeline track
    for w in rec.windows:
        events.append(_x(f"window r{w['round']}"
                         + (" (flush)" if w["kind"] == "flush" else ""),
                         PID_TIMELINE, 0, w["t0"], w["t_close"],
                         {"committed": len(w["committed"]),
                          "pending": w["pending"]}))

    # -- per-device client compute + atomic lumps
    flights = sorted(rec.flights.values(), key=lambda f: f["uid"])
    cids = sorted({f["cid"] for f in flights}
                  | {c for a in rec.atomics for c in a["cids"]}, key=str)
    tid_of = {c: i for i, c in enumerate(cids)}
    for c, tid in tid_of.items():
        events.append(_meta(PID_DEVICES, tid, "thread_name",
                            f"device {c}"))
    for fl in flights:
        tid = tid_of[fl["cid"]]
        r = fl["round"]
        if _fin(fl["dispatch"], fl["up_start"]):
            events.append(_x(f"pre r{r}", PID_DEVICES, tid,
                             fl["dispatch"], fl["up_start"]))
        if _fin(fl["dl_xfer_end"], fl["dl_end"]):
            events.append(_x(f"post r{r}", PID_DEVICES, tid,
                             fl["dl_xfer_end"], fl["dl_end"]))
    for a in rec.atomics:
        for c in a["cids"]:
            events.append(_x(f"round r{a['round']}", PID_DEVICES,
                             tid_of[c], a["start"], a["end"],
                             {"key": str(a["key"])}))

    # -- lane-packed resource tracks: uplink flows, server jobs,
    #    contended downlink transfers
    def _resource(pid, label, spans):
        if not spans:
            return
        lanes = _lanes([(s, e) for s, e, *_ in spans])
        for lane in range(max(lanes) + 1):
            events.append(_meta(pid, lane, "thread_name",
                                f"{label} {lane}"))
        for (s, e, name, args), lane in zip(spans, lanes):
            events.append(_x(name, pid, lane, s, e, args))

    _resource(PID_UPLINK, "flow", [
        (f["up_start"], f["up_end"],
         f"up c{f['cid']} r{f['round']}",
         {"bytes": f["up_bytes"]})
        for f in flights if _fin(f["up_start"], f["up_end"])])
    _resource(PID_SERVER, "slot", [
        (f["srv_start"], f["srv_end"],
         f"srv c{f['cid']} r{f['round']}", None)
        for f in flights if _fin(f["srv_start"], f["srv_end"])])
    _resource(PID_DOWNLINK, "flow", [
        (f["srv_end"], f["dl_xfer_end"],
         f"down c{f['cid']} r{f['round']}", None)
        for f in flights
        if _fin(f["srv_end"], f["dl_xfer_end"])
        and f["dl_xfer_end"] > f["srv_end"] + 1e-12])

    # -- gauge time series as counter tracks
    for name, samples in sorted(rec.gauges.items()):
        for t, v in samples:
            events.append({"name": name, "ph": "C", "pid": PID_TIMELINE,
                           "tid": 0, "ts": t * _US,
                           "args": {"value": v}})

    return {"traceEvents": events, "displayTimeUnit": "ms",
            "s2fl": rec.to_json()}


def write_chrome_trace(rec, path: str) -> dict:
    doc = chrome_trace(rec)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


def load_recorder(path: str):
    """Read a trace file written by ``write_chrome_trace`` back into a
    ``Recorder`` (via the embedded ``"s2fl"`` dump)."""
    from repro.observe.trace import Recorder
    with open(path) as f:
        doc = json.load(f)
    if "s2fl" not in doc:
        raise ValueError(f"{path}: no embedded s2fl recorder dump")
    return Recorder.from_json(doc["s2fl"])
