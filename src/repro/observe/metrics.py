"""Streaming metrics — a small counters / gauges / histograms registry
with a JSONL sink.

The registry is deliberately tiny (no labels, no exposition format): a
name maps to one counter (monotone float), one gauge (last value + the
simulated time it was sampled at), or one histogram (count / sum / min /
max + power-of-two bucket counts). ``snapshot()`` returns a plain dict,
and ``JsonlSink`` appends one JSON object per line to a file — the
long-running-service shape: ``launch/train.py --metrics-out m.jsonl
--metrics-every N`` emits a merged (round record + registry snapshot)
line every N rounds, so a tail -f / ingestion pipeline sees live
progress without waiting for the run to finish.

A ``trace.Recorder`` built with ``metrics=registry`` forwards every
gauge sample and counter increment it receives from the driver hooks
into the registry, so the same hook feeds both the flight-level trace
and the streaming metrics.
"""
from __future__ import annotations

import json
import math


class Histogram:
    """Power-of-two bucketed histogram of positive-ish values (values
    <= 0 land in the underflow bucket ``"-inf"``)."""

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.buckets: dict = {}      # bucket exponent (str) -> count

    def observe(self, value: float):
        v = float(value)
        self.count += 1
        self.total += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        b = "-inf" if v <= 0.0 else str(int(math.floor(math.log2(v))))
        self.buckets[b] = self.buckets.get(b, 0) + 1

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def to_dict(self) -> dict:
        return {"count": self.count, "sum": self.total,
                "min": self.min if self.count else None,
                "max": self.max if self.count else None,
                "mean": self.mean, "buckets": dict(self.buckets)}


class MetricsRegistry:
    """Counters (monotone), gauges (last value wins) and histograms,
    keyed by plain string names. All operations are O(1) upserts."""

    def __init__(self):
        self._counters: dict = {}
        self._gauges: dict = {}      # name -> (value, t)
        self._histos: dict = {}

    # ------------------------------------------------------------ write
    def inc(self, name: str, n: float = 1.0):
        self._counters[name] = self._counters.get(name, 0.0) + float(n)

    def set_gauge(self, name: str, value: float, t: float = None):
        self._gauges[name] = (float(value),
                              float(t) if t is not None else None)

    def observe(self, name: str, value: float):
        if name not in self._histos:
            self._histos[name] = Histogram()
        self._histos[name].observe(value)

    # ------------------------------------------------------------- read
    def counter(self, name: str) -> float:
        return self._counters.get(name, 0.0)

    def gauge(self, name: str):
        """(value, sample_time) or None when never set."""
        return self._gauges.get(name)

    def snapshot(self) -> dict:
        """Plain-dict view of everything in the registry — what the
        JSONL stream carries per emission."""
        return {
            "counters": dict(self._counters),
            "gauges": {k: {"value": v, "t": t}
                       for k, (v, t) in self._gauges.items()},
            "histograms": {k: h.to_dict() for k, h in self._histos.items()},
        }


class JsonlSink:
    """Append-one-JSON-object-per-line sink with per-record flush, so a
    reader following the file sees each record as soon as it is
    emitted (the streaming contract of the service mode)."""

    def __init__(self, path: str):
        self.path = path
        self._f = open(path, "w")
        self.emitted = 0

    def emit(self, record: dict):
        self._f.write(json.dumps(record) + "\n")
        self._f.flush()
        self.emitted += 1

    def close(self):
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self):
        return self

    def __exit__(self, *a):
        self.close()
