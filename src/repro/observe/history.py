"""Streaming per-key round-time history — the control plane's input
signal.

``RoundTimeTracker`` keeps, for every key (a device cid in the driver's
use), an exponential moving average plus a bounded window of recent
observations from which it reports quantile bands. The resource-aware
forecast (``core/control.py``) uses the EMA as the projected completion
horizon and the [q_lo, q_hi] band as the uncertainty envelope it prices
candidate splits across: near a fade boundary the band straddles the
fade, so the worst-case-over-band price anticipates the slow regime
before the EMA alone has drifted there.

Everything is plain floats and lists — no numpy state — so the tracker
round-trips bit-exactly through the driver's JSON checkpoint path.
"""
from __future__ import annotations


class RoundTimeTracker:
    """EMA + bounded-window quantile band per key.

    window  recent observations kept per key (oldest dropped first)
    ema     EMA smoothing factor for the central estimate
    q_lo/q_hi  band quantiles (fractions in [0, 1])
    """

    def __init__(self, window: int = 32, ema: float = 0.3,
                 q_lo: float = 0.25, q_hi: float = 0.9):
        if window < 1:
            raise ValueError(f"window must be >= 1: {window}")
        if not 0.0 < ema <= 1.0:
            raise ValueError(f"ema must be in (0, 1]: {ema}")
        if not 0.0 <= q_lo <= q_hi <= 1.0:
            raise ValueError(f"need 0 <= q_lo <= q_hi <= 1: "
                             f"({q_lo}, {q_hi})")
        self.window = int(window)
        self.ema = float(ema)
        self.q_lo = float(q_lo)
        self.q_hi = float(q_hi)
        self._ema: dict = {}       # key -> EMA of observations
        self._recent: dict = {}    # key -> [most recent `window` values]
        self._count: dict = {}     # key -> total observations ever

    def observe(self, key, t: float):
        t = float(t)
        prev = self._ema.get(key)
        self._ema[key] = t if prev is None \
            else (1.0 - self.ema) * prev + self.ema * t
        w = self._recent.setdefault(key, [])
        w.append(t)
        if len(w) > self.window:
            del w[0]
        self._count[key] = self._count.get(key, 0) + 1

    def n(self, key) -> int:
        return self._count.get(key, 0)

    def ema_of(self, key):
        """EMA of observed times for ``key`` (None before the first)."""
        return self._ema.get(key)

    def quantile(self, key, q: float):
        """Linear-interpolated quantile over the recent window."""
        w = self._recent.get(key)
        if not w:
            return None
        xs = sorted(w)
        if len(xs) == 1:
            return xs[0]
        pos = q * (len(xs) - 1)
        i = int(pos)
        frac = pos - i
        if i + 1 >= len(xs):
            return xs[-1]
        return xs[i] * (1.0 - frac) + xs[i + 1] * frac

    def band(self, key):
        """(lo, ema, hi) horizon band for ``key`` — the quantile
        envelope around the EMA the robust forecast evaluates across
        (None before any observation). The band is widened to contain
        the EMA so the central estimate is always priced too."""
        e = self._ema.get(key)
        if e is None:
            return None
        lo = self.quantile(key, self.q_lo)
        hi = self.quantile(key, self.q_hi)
        return (min(lo, e), e, max(hi, e))

    # ------------------------------------------------- checkpoint state
    def export_state(self) -> dict:
        return {"window": self.window, "ema": self.ema,
                "q_lo": self.q_lo, "q_hi": self.q_hi,
                "emas": sorted(self._ema.items(),
                               key=lambda kv: str(kv[0])),
                "recent": sorted(self._recent.items(),
                                 key=lambda kv: str(kv[0])),
                "counts": sorted(self._count.items(),
                                 key=lambda kv: str(kv[0]))}

    def restore_state(self, st: dict):
        self.window = int(st["window"])
        self.ema = float(st["ema"])
        self.q_lo = float(st["q_lo"])
        self.q_hi = float(st["q_hi"])
        self._ema = {k: float(v) for k, v in st["emas"]}
        self._recent = {k: [float(x) for x in w]
                        for k, w in st["recent"]}
        self._count = {k: int(n) for k, n in st["counts"]}
