"""Flight-level tracing of the event pipeline.

``TraceRecorder`` is the protocol the ``RoundDriver``'s (and
``CommChannel``'s) observability hooks talk to — and simultaneously the
no-op default: every method is a ``pass``, so a driver built without a
recorder (or with the base class) pays nothing and the bit-exact
clock/comm goldens are untouched. ``Recorder`` is the recording
implementation; it captures

  * one **flight record** per pipelined device-round, upserted on every
    round's resource re-solve (latest estimate wins — exactly the
    semantics of the driver's own ``_Flight`` revisions, so once a
    flight's window has closed its record is final).  Span schema (all
    absolute simulated seconds):

        dispatch      phase start (round dispatch clock + gate wait)
        up_start      uplink flow submitted  (= dispatch + t_pre)
        up_end        uplink flow finished (fluid max-min fair solve)
        srv_start     server-compute start (= srv_end - t_srv; the gap
                      up_end → srv_start is FIFO queue wait)
        srv_end       the COMMIT event
        dl_xfer_end   contended dfx transfer landed
        dl_end        download fully drained (client bwd + Wc collect)

  * **atomic records** for device-rounds that do not phase-decompose
    (the non-pipelined path, FedAvg baselines): one (start, end) lump
    per work key;
  * one **window record** per aggregation window (and one per
    ``flush()``): dispatch clock, close clock, committed keys with
    their staleness, events still pending;
  * **gauge samples** (server-queue depth, per-direction link
    utilization and live-flow counts, window staleness, error-feedback
    residual mass, …) and **counters** (messages/bytes per channel
    direction, fluid-solve calls, …).

``critical.py`` turns these records into per-window critical-path
decompositions; ``export.py`` turns them into a Chrome trace-event
(Perfetto-loadable) JSON. ``to_json``/``from_json`` round-trip the full
recorder state, which is how a trace file carries everything the
``benchmarks/trace_report.py`` summarizer needs.
"""
from __future__ import annotations

import numbers


def _jsonable(x):
    """Coerce work keys / cids (possibly numpy scalars, tuples) to
    JSON-safe values that still compare equal after a round-trip."""
    if isinstance(x, bool) or x is None or isinstance(x, str):
        return x
    if isinstance(x, numbers.Integral):
        return int(x)
    if isinstance(x, numbers.Real):
        return float(x)
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    return str(x)


class TraceRecorder:
    """The hook protocol AND the zero-overhead default. Driver /
    channel hook sites guard on ``recorder is not None and
    recorder.enabled``, so with the default recorder (or none at all)
    not even the argument dicts are built."""

    enabled = False

    def flight(self, uid, **fields):
        """Upsert the span record of pipelined flight ``uid``."""

    def atomic(self, key, round, cids, start, end):  # noqa: A002
        """One non-decomposed (atomic Eq.-1) work item."""

    def window(self, round, t0, t_close, committed, pending,  # noqa: A002
               kind="round"):
        """One aggregation window (``kind='flush'`` for the shutdown
        drain). ``committed``: {work key: staleness in rounds}."""

    def gauge(self, name, t, value):
        """Sample a time-series gauge at simulated time ``t``."""

    def count(self, name, n=1.0):
        """Increment a monotone counter."""


NullRecorder = TraceRecorder


class Recorder(TraceRecorder):
    """The recording implementation. Pass ``metrics=`` a
    ``MetricsRegistry`` to additionally forward every gauge sample and
    counter increment into the streaming-metrics registry."""

    enabled = True

    def __init__(self, metrics=None):
        self.flights: dict = {}      # uid -> span record (upserted)
        self.atomics: list = []      # non-decomposed work items
        self.windows: list = []      # aggregation windows, in order
        self.gauges: dict = {}       # name -> [(t, value), ...]
        self.counters: dict = {}     # name -> total
        self.metrics = metrics

    # ------------------------------------------------------------ hooks
    def flight(self, uid, **fields):
        rec = self.flights.setdefault(uid, {"uid": uid})
        rec.update(fields)

    def atomic(self, key, round, cids, start, end):  # noqa: A002
        self.atomics.append({"key": key, "round": round,
                             "cids": list(cids),
                             "start": start, "end": end})

    def window(self, round, t0, t_close, committed, pending,  # noqa: A002
               kind="round"):
        self.windows.append({"round": round, "t0": t0,
                             "t_close": t_close,
                             "committed": dict(committed),
                             "pending": pending, "kind": kind})

    def gauge(self, name, t, value):
        self.gauges.setdefault(name, []).append((t, value))
        if self.metrics is not None:
            self.metrics.set_gauge(name, value, t)

    def count(self, name, n=1.0):
        self.counters[name] = self.counters.get(name, 0.0) + n
        if self.metrics is not None:
            self.metrics.inc(name, n)

    # ---------------------------------------------------------- persist
    def to_json(self) -> dict:
        """JSON-safe dump of the full recorder state (work keys and
        cids coerced; committed dicts stored as pair lists)."""
        return {
            "flights": [
                {k: _jsonable(v) for k, v in fl.items()}
                for _, fl in sorted(self.flights.items())],
            "atomics": [{k: _jsonable(v) for k, v in a.items()}
                        for a in self.atomics],
            "windows": [
                {"round": w["round"], "t0": w["t0"],
                 "t_close": w["t_close"], "pending": w["pending"],
                 "kind": w["kind"],
                 "committed": [[_jsonable(k), int(s)]
                               for k, s in w["committed"].items()]}
                for w in self.windows],
            "gauges": {k: [[t, v] for t, v in vs]
                       for k, vs in self.gauges.items()},
            "counters": dict(self.counters),
        }

    @classmethod
    def from_json(cls, doc: dict) -> "Recorder":
        rec = cls()
        for fl in doc.get("flights", ()):
            fl = dict(fl, key=_key(fl.get("key")))
            rec.flights[fl["uid"]] = fl
        rec.atomics = [dict(a, key=_key(a.get("key")))
                       for a in doc.get("atomics", ())]
        rec.windows = [
            {"round": w["round"], "t0": w["t0"],
             "t_close": w["t_close"], "pending": w["pending"],
             "kind": w.get("kind", "round"),
             "committed": {_key(k): s for k, s in w["committed"]}}
            for w in doc.get("windows", ())]
        rec.gauges = {k: [(t, v) for t, v in vs]
                      for k, vs in doc.get("gauges", {}).items()}
        rec.counters = dict(doc.get("counters", {}))
        return rec


def _key(k):
    """JSON arrays came back as lists; keys must be hashable again."""
    return tuple(k) if isinstance(k, list) else k
