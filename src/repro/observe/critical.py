"""Critical-path extraction: where did each aggregation window's
wall-clock go?

Every window closes on the ready time of some committed event (or does
not advance the clock at all) — see ``RoundDriver._close_window``.
``window_breakdown`` finds that *critical* event among the window's
committed keys, walks the flight record behind it, and decomposes the
window makespan ``t_close - t0`` into additive components:

    dispatch_lag     critical flight's dispatch minus the window's
                     dispatch clock — gate wait (>= 0) for this round's
                     flights, NEGATIVE for a carried straggler that was
                     dispatched in an earlier window
    client_pre       Wc dispatch transfer + client forward (+ latency)
    uplink_xfer      feature payload at the device's OWN link rate
    uplink_wait      extra time the fluid max-min fair schedule charged
                     on the shared ingress (contention stall)
    queue_wait       FIFO wait for a free server slot
    server_compute   the group-backward share (ends at the COMMIT)
    downlink_drain   commit -> download fully drained; nonzero only
                     when the window closed on a download (the flush
                     drain), since downloads never gate round windows
    atomic           the whole lump, for non-decomposed events
    unattributed     makespan with no matching record (should not
                     happen for recorded runs; kept as an honest
                     fallback rather than a silent zero)

The components sum to the makespan *exactly* (floating-point assoc
aside) — the reconstruction property ``tests/test_observe.py`` asserts
at 1e-6 relative tolerance over randomized (uplink, downlink, slots,
latency-dist) regimes. ``summarize`` aggregates the per-window rows
into component totals/fractions and per-device straggler attribution —
the columns ``benchmarks/sweeps.py`` and ``benchmarks/trace_report.py``
surface.
"""
from __future__ import annotations

COMPONENTS = ("dispatch_lag", "client_pre", "uplink_xfer", "uplink_wait",
              "queue_wait", "server_compute", "downlink_drain", "atomic",
              "unattributed")


def flight_components(fl: dict) -> dict:
    """Additive phase decomposition of one flight record, dispatch →
    commit (``downlink_drain`` is appended by the caller only when the
    critical event is the download end, not the commit)."""
    up_xfer = (fl["up_bytes"] / fl["up_rate"]) if fl["up_bytes"] else 0.0
    return {
        "client_pre": fl["t_pre"],
        "uplink_xfer": up_xfer,
        "uplink_wait": (fl["up_end"] - fl["up_start"]) - up_xfer,
        "queue_wait": fl["srv_start"] - fl["up_end"],
        "server_compute": fl["srv_end"] - fl["srv_start"],
    }


def _index(rec):
    """(dispatch round, work key) -> flight records / atomic record."""
    flights: dict = {}
    for fl in rec.flights.values():
        flights.setdefault((fl["round"], fl["key"]), []).append(fl)
    atomics = {(a["round"], a["key"]): a for a in rec.atomics}
    return flights, atomics


def _critical_event(w, flights, atomics):
    """The committed event whose ready time closed the window: among
    the window's committed keys (dispatch round = window round minus
    staleness), the one with the latest commit; for flush windows a
    draining download may be the closer instead."""
    best = None                      # (ready, kind, record)
    for key, stale in w["committed"].items():
        r_d = w["round"] - stale
        cand = None
        fls = flights.get((r_d, key))
        if fls:
            fl = max(fls, key=lambda f: f["srv_end"])
            cand = (fl["srv_end"], "flight", fl)
        a = atomics.get((r_d, key))
        if a is not None and (cand is None or a["end"] > cand[0]):
            # a group may mix pipelined flights with atomic members
            # (e.g. a cost model that only phase-decomposes some
            # devices) — the later ready wins, exactly as the driver's
            # group max does
            cand = (a["end"], "atomic", a)
        if cand is not None and (best is None or cand[0] > best[0]):
            best = cand
    if w["kind"] == "flush":
        # the flush clock waits out draining downloads too — any
        # flight's download end may exceed every commit
        for fls in flights.values():
            for fl in fls:
                if fl["dl_end"] <= w["t_close"] + 1e-12 and (
                        best is None or fl["dl_end"] > best[0]):
                    best = (fl["dl_end"], "drain", fl)
    return best


def window_breakdown(rec) -> list:
    """One row per recorded window: round, t0/t_close, makespan, the
    critical device/key, and the additive component decomposition
    (``sum(components) == makespan`` up to float association)."""
    flights, atomics = _index(rec)
    rows = []
    for w in rec.windows:
        mk = w["t_close"] - w["t0"]
        row = {"round": w["round"], "kind": w["kind"], "t0": w["t0"],
               "t_close": w["t_close"], "makespan": mk,
               "n_committed": len(w["committed"]),
               "critical_cid": None, "critical_key": None,
               "components": {}}
        tol = 1e-9 * max(abs(w["t_close"]), 1.0)
        if mk > tol:
            best = _critical_event(w, flights, atomics)
            if best is None or abs(best[0] - w["t_close"]) > 1e-6 * max(
                    abs(w["t_close"]), 1.0):
                row["components"] = {"unattributed": mk}
            else:
                _, kind, ev = best
                if kind == "atomic":
                    row["critical_key"] = ev["key"]
                    row["critical_cid"] = (ev["cids"][0]
                                           if len(ev["cids"]) == 1
                                           else None)
                    row["components"] = {
                        "dispatch_lag": ev["start"] - w["t0"],
                        "atomic": ev["end"] - ev["start"]}
                else:
                    comp = flight_components(ev)
                    comp["dispatch_lag"] = ev["dispatch"] - w["t0"]
                    comp["downlink_drain"] = (
                        ev["dl_end"] - ev["srv_end"]
                        if kind == "drain" else 0.0)
                    row["critical_cid"] = ev["cid"]
                    row["critical_key"] = ev["key"]
                    row["components"] = comp
        row["reconstructed"] = sum(row["components"].values())
        rows.append(row)
    return rows


def verify_reconstruction(rec, rel: float = 1e-6) -> float:
    """Max relative reconstruction error over all windows (raises
    AssertionError when any window exceeds ``rel``) — the acceptance
    property, also asserted by the benchmark surfaces so a trace that
    stops reconstructing fails loudly."""
    worst = 0.0
    for row in window_breakdown(rec):
        scale = max(abs(row["makespan"]), 1.0)
        err = abs(row["reconstructed"] - row["makespan"]) / scale
        worst = max(worst, err)
        assert err <= rel, (row, err)
    return worst


def summarize(rec) -> dict:
    """Aggregate the per-window rows: total/fractional time per
    component across all windows, per-device straggler counts (how
    often each device's flight was the critical one), and the worst
    reconstruction error."""
    rows = window_breakdown(rec)
    totals = {}
    stragglers: dict = {}
    straggler_time: dict = {}
    total_mk = 0.0
    worst = 0.0
    for row in rows:
        total_mk += row["makespan"]
        scale = max(abs(row["makespan"]), 1.0)
        worst = max(worst,
                    abs(row["reconstructed"] - row["makespan"]) / scale)
        for k, v in row["components"].items():
            totals[k] = totals.get(k, 0.0) + v
        cid = row["critical_cid"]
        if cid is not None and row["makespan"] > 0.0:
            stragglers[cid] = stragglers.get(cid, 0) + 1
            straggler_time[cid] = straggler_time.get(cid, 0.0) \
                + row["makespan"]
    fractions = {k: (v / total_mk if total_mk > 0 else 0.0)
                 for k, v in totals.items()}
    top = max(straggler_time, key=straggler_time.get) \
        if straggler_time else None
    return {"windows": len(rows), "total_makespan": total_mk,
            "totals": totals, "fractions": fractions,
            "stragglers": stragglers, "straggler_time": straggler_time,
            "top_straggler": top, "max_reconstruction_err": worst}
