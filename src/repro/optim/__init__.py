"""Optimizers (built from scratch — no optax): SGD(+momentum), Adam,
global-norm clipping, LR schedules. Functional (init, update) pairs over
arbitrary pytrees. The paper trains everything with plain SGD lr=0.01
(§5); Adam is provided for the beyond-paper training drivers.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable          # params -> state
    update: Callable        # (params, grads, state, step) -> (params, state)


def _lr_at(lr, step):
    return lr(step) if callable(lr) else lr


def sgd(lr, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree.map(jnp.zeros_like, params)

    def update(params, grads, state, step=0):
        eta = _lr_at(lr, step)
        if momentum == 0.0:
            new = jax.tree.map(
                lambda p, g: (p - eta * g.astype(p.dtype)).astype(p.dtype),
                params, grads)
            return new, state
        vel = jax.tree.map(lambda v, g: momentum * v + g.astype(v.dtype),
                           state, grads)
        new = jax.tree.map(lambda p, v: (p - eta * v).astype(p.dtype),
                           params, vel)
        return new, vel

    return Optimizer(init, update)


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(params, grads, state, step=0):
        eta = _lr_at(lr, step)
        t = step + 1
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) *
                         g.astype(jnp.float32), state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) *
                         jnp.square(g.astype(jnp.float32)), state["v"], grads)
        def upd(p, m_, v_):
            mh = m_ / (1 - b1 ** t)
            vh = v_ / (1 - b2 ** t)
            step_ = eta * (mh / (jnp.sqrt(vh) + eps)
                           + weight_decay * p.astype(jnp.float32))
            return (p.astype(jnp.float32) - step_).astype(p.dtype)
        new = jax.tree.map(upd, params, m, v)
        return new, {"m": m, "v": v}

    return Optimizer(init, update)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1):
    def lr(step):
        w = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                        0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * w * cos
    return lr
