"""Full-training-state snapshots: everything an ``S2FLEngine`` run needs
to resume bit-exactly, in ONE ``.npz``.

``save_checkpoint``'s pytree layer carries every array (model params,
the jax PRNG key, error-feedback residuals — live and quarantined —
and the un-committed held work's client/server copies), while the JSON
``extra`` side-channel carries the simulator state: the driver's whole
timeline (clock, event/download heaps, live flights, FluidLink flows,
server queue, fault ledger, scheduler EMA table), the channel's byte
meters + stateful-codec stream positions, the numpy Generator state,
and the run history.

Bit-exactness argument: every float crosses JSON via ``repr`` (exact
round-trip), arrays cross ``.npz`` verbatim, the np/jax RNG states are
restored to the word, and the driver/channel/scheduler restores rebuild
the exact heaps and maps — so on the fp32 sync path a crash-and-resume
run replays the uninterrupted run's arithmetic operation-for-operation
(property-tested in tests/test_chaos.py).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def _as_jnp(tree):
    import jax
    return jax.tree.map(jnp.asarray, tree)


def save_run_state(path: str, engine) -> None:
    """Snapshot ``engine`` (an ``S2FLEngine``) between rounds."""
    from repro.checkpoint import save_checkpoint
    held_arrays, held_meta = {}, {}
    if engine.ecfg.mode == "fedavg":
        for gid, (params, weight) in engine._held.items():
            held_arrays[str(gid)] = {"params": params}
            held_meta[str(gid)] = float(weight)
    else:
        for gid, (states, server_copy) in engine._held.items():
            held_arrays[str(gid)] = {
                "server": server_copy,
                "clients": [st.params for st in states]}
            held_meta[str(gid)] = [[st.cid, st.split, st.data_size,
                                    st.group] for st in states]
    tree = {"params": engine.params,
            "prng_key": engine._key,
            "residuals": engine.channel.export_residual_state(),
            "held": held_arrays}
    extra = {"format": "s2fl-run-state-v1",
             "mode": engine.ecfg.mode,
             "history": engine.history,
             "next_gid": engine._next_gid,
             "rng_state": engine.rng.bit_generator.state,
             "driver": engine.driver.export_state(),
             "channel": engine.channel.export_state(),
             "held_meta": held_meta}
    save_checkpoint(path, tree, extra=extra)


def restore_run_state(path: str, engine) -> dict:
    """Restore a ``save_run_state`` snapshot into a freshly-constructed,
    identically-configured engine. Returns the ``extra`` metadata (the
    restored ``history`` is also installed on the engine, so
    ``len(engine.history)`` is the next round index)."""
    from repro.checkpoint import load_checkpoint
    from repro.core.aggregation import ClientState
    tree, extra = load_checkpoint(path)
    if extra.get("format") != "s2fl-run-state-v1":
        raise ValueError(f"{path}: not a run-state checkpoint "
                         f"(format={extra.get('format')!r})")
    if extra["mode"] != engine.ecfg.mode:
        raise ValueError(
            f"checkpoint mode {extra['mode']!r} != engine mode "
            f"{engine.ecfg.mode!r} — reconstruct the engine with the "
            "config the run was started with")
    engine.params = _as_jnp(tree["params"])
    engine._key = jnp.asarray(tree["prng_key"])
    engine.channel.restore_residual_state(
        {k: jnp.asarray(v) for k, v in tree["residuals"].items()})
    engine.channel.restore_state(extra["channel"])
    engine.driver.restore_state(extra["driver"])
    engine.rng = np.random.default_rng()
    engine.rng.bit_generator.state = extra["rng_state"]
    engine.history = list(extra["history"])
    engine._next_gid = int(extra["next_gid"])
    engine._held = {}
    held_arrays = tree.get("held", {})
    for sgid, meta in extra["held_meta"].items():
        gid = int(sgid)
        if engine.ecfg.mode == "fedavg":
            engine._held[gid] = (_as_jnp(held_arrays[sgid]["params"]),
                                 float(meta))
        else:
            clients = held_arrays[sgid]["clients"]
            states = [ClientState(cid=cid, params=_as_jnp(clients[i]),
                                  split=int(split),
                                  data_size=float(dsz), group=gid)
                      for i, (cid, split, dsz, _g) in enumerate(meta)]
            engine._held[gid] = (states,
                                 _as_jnp(held_arrays[sgid]["server"]))
    return extra
