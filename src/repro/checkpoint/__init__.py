"""Pytree checkpointing to .npz (flat path-keyed arrays + structure)."""
from __future__ import annotations

import json
import os

import jax
import numpy as np


def _flatten(tree, prefix=""):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], f"{prefix}/{k}")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten(v, f"{prefix}/{i}")
    else:
        yield prefix, tree


def save_checkpoint(path: str, params, extra: dict | None = None):
    flat = dict(_flatten(params))
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    meta = {"structure": jax.tree.structure(params).__repr__(),
            "extra": extra or {}}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, __meta__=json.dumps(meta), **arrays)


def load_checkpoint(path: str, like):
    """Restore into the structure of `like` (a params pytree or abstract
    tree with the same paths)."""
    with np.load(path, allow_pickle=False) as z:
        flat = {k: z[k] for k in z.files if k != "__meta__"}
        meta = json.loads(str(z["__meta__"]))
    paths = [p for p, _ in _flatten(like)]
    assert set(paths) == set(flat), (
        f"checkpoint/model mismatch: {set(paths) ^ set(flat)}")
    leaves = [flat[p] for p, _ in _flatten(like)]
    ref_leaves, treedef = jax.tree.flatten(like)
    # _flatten order (sorted dict keys) must match tree.flatten order for
    # dicts (jax sorts keys) and lists (index order) — identical here.
    return jax.tree.unflatten(treedef, leaves), meta["extra"]
