"""Pytree checkpointing to .npz (flat path-keyed arrays + structure).

The structure travels as a JSON skeleton (dict/list/tuple nesting with
dict keys), not a ``repr()`` string: ``load_checkpoint`` can rebuild the
saved pytree with NO reference tree at all, and when a ``like`` tree IS
supplied its paths are checked against the file's with a clear
``ValueError`` on mismatch instead of silently rebuilding something
shaped like neither.

``save_run_state`` / ``restore_run_state`` (checkpoint/state.py) build
the FULL-training-state snapshot — driver timeline, link flows, channel
codec + residual state, scheduler table, rng — on top of these
primitives.
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np

from repro.checkpoint.state import (restore_run_state,  # noqa: F401
                                    save_run_state)


def _flatten(tree, prefix=""):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], f"{prefix}/{k}")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten(v, f"{prefix}/{i}")
    else:
        yield prefix, tree


def _skeleton(tree):
    """JSON-serializable structure of a dict/list/tuple pytree — enough
    to rebuild it from the flat path-keyed arrays without a reference."""
    if isinstance(tree, dict):
        return {"k": "d", "keys": sorted(tree),
                "children": [_skeleton(tree[k]) for k in sorted(tree)]}
    if isinstance(tree, (list, tuple)):
        return {"k": "l" if isinstance(tree, list) else "t",
                "children": [_skeleton(v) for v in tree]}
    return {"k": "leaf"}


def _build(skel, flat, prefix=""):
    """Rebuild the pytree described by ``skel`` from ``flat`` (path ->
    array) — the exact mirror of ``_flatten``'s path scheme."""
    kind = skel["k"]
    if kind == "d":
        return {k: _build(c, flat, f"{prefix}/{k}")
                for k, c in zip(skel["keys"], skel["children"])}
    if kind in ("l", "t"):
        seq = [_build(c, flat, f"{prefix}/{i}")
               for i, c in enumerate(skel["children"])]
        return seq if kind == "l" else tuple(seq)
    return flat[prefix]


def _json_default(o):
    """np scalars (e.g. int64 cids) -> plain Python for json.dumps."""
    if hasattr(o, "item"):
        return o.item()
    raise TypeError(f"not JSON-serializable: {type(o).__name__}")


def save_checkpoint(path: str, params, extra: dict | None = None):
    flat = dict(_flatten(params))
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    meta = {"skeleton": _skeleton(params), "extra": extra or {}}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, __meta__=json.dumps(meta, default=_json_default),
             **arrays)


def load_checkpoint(path: str, like=None):
    """Restore the saved pytree. Without ``like`` the file's own
    skeleton rebuilds the structure (dicts/lists/tuples round-trip
    exactly); with ``like`` the restored leaves are additionally poured
    into ``like``'s treedef after checking the paths match — a
    checkpoint/model mismatch raises ``ValueError`` naming the
    differing paths instead of silently rebuilding."""
    with np.load(path, allow_pickle=False) as z:
        flat = {k: z[k] for k in z.files if k != "__meta__"}
        meta = json.loads(str(z["__meta__"]))
    if "skeleton" not in meta:
        raise ValueError(f"{path}: no structure skeleton in checkpoint "
                         "(pre-skeleton format is not supported)")
    params = _build(meta["skeleton"], flat)
    if like is not None:
        paths = [p for p, _ in _flatten(like)]
        if set(paths) != set(flat):
            diff = sorted(set(paths) ^ set(flat))
            raise ValueError(
                f"checkpoint/model structure mismatch at {len(diff)} "
                f"path(s): {diff[:8]}{'...' if len(diff) > 8 else ''}")
        leaves = [flat[p] for p in paths]
        _, treedef = jax.tree.flatten(like)
        # _flatten order (sorted dict keys) matches tree.flatten order
        # for dicts (jax sorts keys) and lists (index order).
        return jax.tree.unflatten(treedef, leaves), meta["extra"]
    return params, meta["extra"]
