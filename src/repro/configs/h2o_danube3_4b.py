"""h2o-danube-3-4b — llama+mistral mix with sliding-window attention
[arXiv:2401.16818].

24L, d_model=3840, 32 heads (GQA kv=8, head_dim=120), d_ff=10240,
vocab=32000, SWA window 4096 (mistral-style) -> eligible for long_500k.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="h2o-danube-3-4b",
    arch_type="dense",
    n_layers=24,
    d_model=3840,
    vocab_size=32000,
    n_heads=32,
    n_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    block_pattern=("swa",) * 24,
    ffn_pattern=("dense",) * 24,
    sliding_window=4096,
    source="H2O-Danube(-3) [arXiv:2401.16818]",
))
