"""ResNet-8 for CIFAR — the paper's smallest model [He et al. 2016].

Stem conv + 3 residual stages (1 basic block each) + linear head = 8
weighted layers. ``stages`` = (channels, n_blocks, stride) per stage.
"""
from repro.configs.base import CNNConfig, register

CONFIG = register(CNNConfig(
    name="resnet8",
    family="resnet",
    stages=((16, 1, 1), (32, 1, 2), (64, 1, 2)),
    source="ResNet [He et al., CVPR 2016]; S2FL paper Sec. 5.1",
))
