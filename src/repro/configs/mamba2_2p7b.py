"""mamba2-2.7b — SSD (state-space duality) [arXiv:2405.21060].

Attention-free: 64 Mamba2 (SSD) blocks, d_model=2560, ssm_state=128,
expand=2 (d_inner=5120), head_dim=64 -> 80 SSD heads, vocab 50280.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-2.7b",
    arch_type="ssm",
    n_layers=64,
    d_model=2560,
    vocab_size=50280,
    d_ff=0,
    block_pattern=("ssm",) * 64,
    ffn_pattern=("none",) * 64,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=128,
    tie_embeddings=True,
    remat=True,
    source="SSD / Mamba2 [arXiv:2405.21060]",
))
