"""zamba2-1.2b — hybrid: Mamba2 backbone + shared attention block
[arXiv:2411.15242].

38 Mamba2 blocks, d_model=2048, ssm_state=64; ONE shared attention+MLP
block (32 heads, kv=32, d_ff=8192) invoked every 6th layer (its params are
shared across invocations and aggregated once, Alg. 1).
"""
from repro.configs.base import ModelConfig, register

_L = 38
_pattern = tuple("shared_attn" if (i % 6) == 5 else "ssm" for i in range(_L))
_ffn = tuple("dense" if k == "shared_attn" else "none" for k in _pattern)

CONFIG = register(ModelConfig(
    name="zamba2-1.2b",
    arch_type="hybrid",
    n_layers=_L,
    d_model=2048,
    vocab_size=32000,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    block_pattern=_pattern,
    ffn_pattern=_ffn,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=128,
    shared_attn_every=6,
    tie_embeddings=True,
    source="Zamba2 [arXiv:2411.15242]",
))
