"""Config system: ModelConfig dataclass, registry, reduced variants.

Every assigned architecture gets one file in this package exporting
``CONFIG``; the registry maps the public ``--arch`` id to it. Paper-native
CNN architectures (ResNet8 / VGG16 / MobileNet) use ``CNNConfig`` and are
used by the paper-reproduction benchmarks rather than the pod dry-run.
"""
from __future__ import annotations

import dataclasses


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Configuration for a decoder-style model (dense / moe / ssm / hybrid /
    vlm / audio backbones)."""

    name: str
    arch_type: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    vocab_size: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    # Per-layer mixer pattern; entries: 'attn' | 'swa' | 'ssm' | 'shared_attn'.
    # FFN kind per layer: 'dense' | 'moe' | 'none' (parallel list, same length).
    block_pattern: tuple = ()
    ffn_pattern: tuple = ()
    # attention
    rope_theta: float = 10000.0
    sliding_window: int = 0             # window size for 'swa' blocks
    # MLA (deepseek-style latent attention)
    mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_rope_head_dim: int = 0
    qk_nope_head_dim: int = 0
    v_head_dim: int = 0
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    router_aux_coef: float = 0.001
    moe_dispatch_shards: int = 0        # >1: shard-local dispatch (moe.py)
    moe_dispatch_axes: tuple = ()       # mesh axes of the shard dim
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128
    # shared-attention hybrid (zamba2-style): one shared block reused every
    # `shared_attn_every` layers.
    shared_attn_every: int = 0
    # misc
    act: str = "silu"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"             # activation / compute dtype
    param_dtype: str = "float32"
    remat: bool = False                 # jax.checkpoint each block in training
    remat_policy: str = ""              # '' (full) | 'dots' (save matmul
                                        # outputs, recompute elementwise)
    scan_layers: bool = False           # lax.scan over identical-block runs
    attn_impl: str = "xla"              # 'xla' | 'pallas' (pallas: interpret on CPU)
    # modality frontend stub ('' | 'audio' | 'vision'): input_specs() provides
    # precomputed frame/patch embeddings of shape (B, n_prefix, d_model).
    frontend: str = ""
    n_frontend_tokens: int = 0
    # sharding hints (see models/sharding.py)
    fsdp_ff: bool = False               # additionally shard ff/expert-ff over 'data'
    source: str = ""                    # citation / model card

    # ---- derived ----
    @property
    def vocab_padded(self) -> int:
        return _round_up(self.vocab_size, 128)

    @property
    def d_inner(self) -> int:           # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def qk_head_dim(self) -> int:
        if self.mla:
            return self.qk_rope_head_dim + self.qk_nope_head_dim
        return self.head_dim

    def __post_init__(self):
        if self.block_pattern:
            assert len(self.block_pattern) == self.n_layers, self.name
            assert len(self.ffn_pattern) == self.n_layers, self.name
        if self.ssm_state:
            assert self.d_inner % self.ssm_head_dim == 0, self.name

    def pattern(self):
        """(mixer, ffn) kind per layer, defaulting to all-attn/all-dense."""
        bp = self.block_pattern or ("attn",) * self.n_layers
        fp = self.ffn_pattern or ("dense",) * self.n_layers
        return tuple(zip(bp, fp))


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    """Paper-native CNN configs (ResNet8 / VGG16 / MobileNet on CIFAR)."""

    name: str
    family: str                         # resnet | vgg | mobilenet
    n_classes: int = 10
    in_channels: int = 3
    image_size: int = 32
    width_mult: float = 1.0
    # family-specific stage description, consumed by models/cnn.py
    stages: tuple = ()
    source: str = ""
    arch_type: str = "cnn"
    dtype: str = "float32"
    param_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class CommConfig:
    """Transport knobs for the cut-layer exchange (repro.comm).

    ``uplink_codec`` compresses uplink features, ``downlink_codec`` the
    downlink feature-gradients ('' -> same as uplink), and
    ``dispatch_codec`` the model legs (Wc dispatch/collect, and the
    FedAvg broadcast + QSGD-style update upload). ``codec`` /
    ``grad_codec`` are the original names for the first two and remain
    the storage fields; the ``*_codec`` aliases override them when set.
    ``error_feedback`` turns on the channel's per-(device, tensor)
    residual accumulators (compression error is added back before the
    next round's encode); ``topk_frac`` sets the kept fraction of the
    'topk'/'randk' sparsifiers. ``link`` selects the rate model:
    'static' (Table 1) or 'trace' (time-varying multiplier schedule —
    inline via trace_* fields or a JSON file, see comm/README.md).
    ``latency`` adds a per-message delay (four messages per
    device-round); with a non-constant ``latency_dist`` each
    device-round draws its own latency around that mean (uniform /
    lognormal / exp, spread ``latency_jitter``, deterministic per
    (latency_seed, device, round)). ``uplink_capacity`` bounds the Main
    Server's shared ingress and ``downlink_capacity`` its shared egress
    (Table-1 elements/s, 0 = uncontended) — concurrent uploads and
    dfx downloads in the phase pipeline then contend for them under the
    same max-min fair fluid schedule, with in-flight flows carried
    across aggregation windows."""

    codec: str = "fp32"                 # fp32|bf16|fp16|int8|topk|randk
    grad_codec: str = ""                # '' -> follow codec
    uplink_codec: str = ""              # alias: overrides codec when set
    downlink_codec: str = ""            # alias: overrides grad_codec
    dispatch_codec: str = "fp32"        # model legs (Wc / FedAvg W)
    error_feedback: bool = False        # residual accumulators on
    topk_frac: float = 0.1              # kept fraction for topk/randk
    link: str = "static"                # static | trace
    trace_times: tuple = ()             # ascending, starts at 0.0
    trace_multipliers: tuple = ()       # same length, > 0
    trace_period: float = 0.0           # 0 -> trace_times[-1]
    trace_phase_per_device: bool = True
    trace_file: str = ""                # JSON overrides the inline trace
    latency: float = 0.0                # seconds per message (the mean)
    latency_dist: str = "constant"      # constant|uniform|lognormal|exp
    latency_jitter: float = 0.5         # spread of the non-constant dists
    latency_seed: int = 0               # latency draw stream seed
    uplink_capacity: float = 0.0        # shared elements/s; 0 = off
    downlink_capacity: float = 0.0      # shared egress; 0 = off


@dataclasses.dataclass(frozen=True)
class DriverConfig:
    """Round-loop execution knobs (repro.core.driver.RoundDriver).

    ``exec_mode='sync'`` is the paper's Eq.-1 barrier (the round clock
    advances by the max participant time). ``'semi_async'`` turns device
    completions into heap events: the aggregation window closes at a
    ``quorum`` fraction of this round's arrivals and stragglers commit
    up to ``staleness_cap`` rounds late (0 degenerates to sync).
    ``predictive`` makes the sliding scheduler re-price its EMA table
    with the link model's rate over the projected completion window.
    ``pipeline`` splits each device-round into upload / server-compute /
    download phase events: a group's update commits when its server
    backward finishes (downloads drain in the background), and
    concurrent uploads contend for ``CommConfig.uplink_capacity``.
    ``server_concurrency`` bounds the Main Server GPU to that many
    concurrent group backwards (FIFO queue; 0 = unbounded, the
    free-overlap regime) and ``gate_redispatch`` makes a device wait
    out its own draining download before it can start the next round's
    upload — both only observable under ``pipeline``.
    ``resource_aware`` upgrades the forecast from the link model's mean
    rate to a ResourceView over live driver state (queue depth, fluid
    backlogs, draining flows, learned horizon band — core/control.py);
    ``auto_knobs`` lets an AggregationController probe nearby
    (quorum, staleness_cap) pairs and lock the fastest (semi-async
    only).
    ``fleet_size`` switches the population to batched (P,) fleet tables
    (core/fleet.py): cohorts are fleet-sampled, Device objects
    materialize only for sampled cids. ``clusters`` > 1 turns on
    hierarchical aggregation (devices → edge clusters → main server):
    each cluster closes at its own ``cluster_quorum`` quantile, the
    global window at ``quorum`` over the cluster close times."""

    exec_mode: str = "sync"             # sync | semi_async
    staleness_cap: int = 1              # max rounds an update may lag
    quorum: float = 0.5                 # window-close arrival fraction
    predictive: bool = False            # link-aware split forecasts
    pipeline: bool = False              # phase-level event pipeline
    server_concurrency: int = 0         # server backward slots; 0 = inf
    gate_redispatch: bool = False       # wait out own draining download
    resource_aware: bool = False        # physics-priced split forecasts
    auto_knobs: bool = False            # probe quorum/staleness pairs
    fleet_size: int = 0                 # batched population (0 = object grid)
    clusters: int = 0                   # edge clusters (<=1 = flat window)
    cluster_quorum: float = 1.0         # per-cluster close quantile


def make_reduced(cfg: ModelConfig, *, n_layers: int = 2, d_model: int = 256,
                 vocab: int = 512) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests (<=2 layers,
    d_model<=512, <=4 experts)."""
    d_model = min(d_model, cfg.d_model)
    scale = d_model / cfg.d_model
    def sc(x, m=8):
        return max(m, _round_up(int(x * scale), m)) if x else 0

    n_heads = max(2, min(cfg.n_heads, d_model // 64)) if cfg.n_heads else 0
    head_dim = 64 if cfg.n_heads else 0
    n_kv = 0
    if cfg.n_kv_heads:
        n_kv = max(1, n_heads * cfg.n_kv_heads // max(cfg.n_heads, 1))
        while n_heads % n_kv:
            n_kv -= 1
    bp = cfg.block_pattern and _reduce_pattern(cfg.block_pattern, n_layers)
    fp = cfg.ffn_pattern and _reduce_pattern(cfg.ffn_pattern, n_layers)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-reduced",
        n_layers=n_layers,
        d_model=d_model,
        vocab_size=min(cfg.vocab_size, vocab),
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=sc(cfg.d_ff, 16),
        block_pattern=tuple(bp),
        ffn_pattern=tuple(fp),
        kv_lora_rank=sc(cfg.kv_lora_rank, 8),
        q_lora_rank=sc(cfg.q_lora_rank, 8),
        qk_rope_head_dim=32 if cfg.mla else 0,
        qk_nope_head_dim=32 if cfg.mla else 0,
        v_head_dim=64 if cfg.mla else 0,
        n_experts=min(cfg.n_experts, 4),
        n_shared_experts=min(cfg.n_shared_experts, 1),
        top_k=min(cfg.top_k, 2),
        moe_d_ff=sc(cfg.moe_d_ff, 16),
        ssm_state=min(cfg.ssm_state, 32),
        ssm_head_dim=min(cfg.ssm_head_dim, 32) if cfg.ssm_state else cfg.ssm_head_dim,
        ssm_chunk=32,
        shared_attn_every=min(cfg.shared_attn_every, 2) if cfg.shared_attn_every else 0,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        n_frontend_tokens=min(cfg.n_frontend_tokens, 16) if cfg.frontend else 0,
        remat=False,
        dtype="float32",
    )


def _reduce_pattern(pattern, n_layers):
    """Keep the flavour of a layer pattern in n_layers slots (ensure at least
    one of each distinct kind appears when possible)."""
    kinds = []
    for k in pattern:
        if k not in kinds:
            kinds.append(k)
    out = list(kinds[:n_layers])
    while len(out) < n_layers:
        out.append(pattern[len(out) % len(pattern)])
    return tuple(out[:n_layers])


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
_REGISTRY: dict = {}


def register(cfg):
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str):
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs():
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False


def _ensure_loaded():
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    from repro.configs import (  # noqa: F401
        mamba2_2p7b, internlm2_1p8b, musicgen_medium, deepseek_v2_lite_16b,
        h2o_danube3_4b, kimi_k2_1t_a32b, gemma3_27b, stablelm_3b,
        zamba2_1p2b, internvl2_1b, resnet8, vgg16, mobilenet)
