"""internlm2-1.8b — dense GQA [arXiv:2403.17297].

24L, d_model=2048, 16 heads (GQA kv=8, head_dim=128), d_ff=8192,
vocab=92544.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internlm2-1.8b",
    arch_type="dense",
    n_layers=24,
    d_model=2048,
    vocab_size=92544,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    block_pattern=("attn",) * 24,
    ffn_pattern=("dense",) * 24,
    rope_theta=1_000_000.0,
    source="InternLM2 [arXiv:2403.17297]",
))
