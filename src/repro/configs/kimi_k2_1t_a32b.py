"""kimi-k2-1t-a32b — trillion-parameter MoE (paper-table config)
[arXiv:2501.kimi2].

61L, d_model=7168, 64 heads (GQA kv=8, head_dim=112), MoE 384 routed
experts top-8 + 1 shared, expert d_ff=2048, first layer dense, vocab=163840.
~1T total / ~32B active parameters. bf16 params + plain SGD (the paper's
optimizer) keep the dry-run per-chip footprint feasible; expert FFN dims
additionally shard over the data axis (fsdp_ff).
"""
from repro.configs.base import ModelConfig, register

_L = 61
CONFIG = register(ModelConfig(
    name="kimi-k2-1t-a32b",
    arch_type="moe",
    n_layers=_L,
    d_model=7168,
    vocab_size=163840,
    n_heads=64,
    n_kv_heads=8,
    head_dim=112,
    d_ff=18432,
    block_pattern=("attn",) * _L,
    ffn_pattern=("dense",) + ("moe",) * (_L - 1),
    n_experts=384,
    n_shared_experts=1,
    top_k=8,
    moe_d_ff=2048,
    param_dtype="bfloat16",
    fsdp_ff=True,
    remat=True,
    scan_layers=True,    # 61-layer unrolled train HLO is intractable to
                         # partition at 512 ways; see EXPERIMENTS §Perf

    source="Kimi K2 [arXiv:2501.kimi2]",
))
