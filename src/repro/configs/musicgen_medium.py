"""musicgen-medium — decoder-only over EnCodec tokens [arXiv:2306.05284].

48L, d_model=1536, 24 heads (kv=24, MHA), d_ff=6144, vocab=2048 (EnCodec
codebook). The EnCodec conv codec frontend is a STUB per the brief:
input_specs() provides precomputed frame embeddings.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="musicgen-medium",
    arch_type="audio",
    n_layers=48,
    d_model=1536,
    vocab_size=2048,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    block_pattern=("attn",) * 48,
    ffn_pattern=("dense",) * 48,
    act="gelu",
    frontend="audio",
    n_frontend_tokens=256,
    source="MusicGen [arXiv:2306.05284]",
))
