"""gemma3-27b — dense, 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt family].

62L, d_model=5376, 32 heads (GQA kv=16, head_dim=128), d_ff=21504,
vocab=262144. Pattern: 5 sliding-window (1024) layers then 1 global layer.
long_500k decode runs via window caches (local) + sequence-sharded global
KV cache.
"""
from repro.configs.base import ModelConfig, register

_L = 62
_pattern = tuple("attn" if (i % 6) == 5 else "swa" for i in range(_L))

CONFIG = register(ModelConfig(
    name="gemma3-27b",
    arch_type="dense",
    n_layers=_L,
    d_model=5376,
    vocab_size=262144,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    block_pattern=_pattern,
    ffn_pattern=("dense",) * _L,
    sliding_window=1024,
    rope_theta=1_000_000.0,
    act="gelu",
    param_dtype="bfloat16",
    remat=True,
    source="Gemma 3 [hf:google/gemma-3-1b-pt family]",
))
