"""stablelm-3b — dense MHA [hf:stabilityai/stablelm-2-1_6b family].

32L, d_model=2560, 32 heads (kv=32 MHA, head_dim=80), d_ff=6912,
vocab=50304.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="stablelm-3b",
    arch_type="dense",
    n_layers=32,
    d_model=2560,
    vocab_size=50304,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=6912,
    block_pattern=("attn",) * 32,
    ffn_pattern=("dense",) * 32,
    source="StableLM [hf:stabilityai/stablelm-2-1_6b]",
))
