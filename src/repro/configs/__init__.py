from repro.configs.base import (CNNConfig, CommConfig, ModelConfig,
                                get_config, list_configs, make_reduced,
                                register)

__all__ = ["ModelConfig", "CNNConfig", "CommConfig", "get_config",
           "list_configs", "make_reduced", "register"]
