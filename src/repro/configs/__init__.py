from repro.configs.base import (CNNConfig, ModelConfig, get_config,
                                list_configs, make_reduced, register)

__all__ = ["ModelConfig", "CNNConfig", "get_config", "list_configs",
           "make_reduced", "register"]
