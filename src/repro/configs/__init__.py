from repro.configs.base import (CNNConfig, CommConfig, DriverConfig,
                                ModelConfig, get_config, list_configs,
                                make_reduced, register)

__all__ = ["ModelConfig", "CNNConfig", "CommConfig", "DriverConfig",
           "get_config", "list_configs", "make_reduced", "register"]
