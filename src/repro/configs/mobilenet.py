"""MobileNet-v1 (CIFAR variant) — depthwise-separable convs
[arXiv:1704.04861]. ``stages`` = (channels, stride) per separable block.
"""
from repro.configs.base import CNNConfig, register

CONFIG = register(CNNConfig(
    name="mobilenet",
    family="mobilenet",
    stages=((64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2),
            (512, 1), (512, 1), (512, 1), (512, 1), (512, 1), (1024, 2),
            (1024, 1)),
    source="MobileNet [arXiv:1704.04861]; S2FL paper Sec. 5.1",
))
