"""internvl2-1b — VLM: InternViT + Qwen2-0.5B-family LM [arXiv:2404.16821].

LM backbone: 24L, d_model=896, 14 heads (GQA kv=2, head_dim=64),
d_ff=4864, vocab=151655. The InternViT vision encoder + projector is a
STUB per the brief: input_specs() provides precomputed patch embeddings
(n_frontend_tokens x d_model) prepended to the token stream.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-1b",
    arch_type="vlm",
    n_layers=24,
    d_model=896,
    vocab_size=151655,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    block_pattern=("attn",) * 24,
    ffn_pattern=("dense",) * 24,
    rope_theta=1_000_000.0,
    frontend="vision",
    n_frontend_tokens=256,
    tie_embeddings=True,
    source="InternVL2 [arXiv:2404.16821]",
))
