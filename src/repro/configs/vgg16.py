"""VGG-16 (CIFAR variant) — the paper's large model [Simonyan & Zisserman
2014]. ``stages`` is the classic VGG-16 conv plan: (channels, n_convs) per
max-pool stage.
"""
from repro.configs.base import CNNConfig, register

CONFIG = register(CNNConfig(
    name="vgg16",
    family="vgg",
    stages=((64, 2), (128, 2), (256, 3), (512, 3), (512, 3)),
    source="VGG [arXiv:1409.1556]; S2FL paper Sec. 5.1",
))
