"""deepseek-v2-lite-16b — MoE with MLA [arXiv:2405.04434].

27L, d_model=2048, 16 heads, MLA kv_lora=512 (rope 64 + nope 128, v 128),
first layer dense (d_ff=10944), 26 MoE layers: 64 routed experts top-6 +
2 shared experts, expert d_ff=1408, vocab=102400.
"""
from repro.configs.base import ModelConfig, register

_L = 27
CONFIG = register(ModelConfig(
    name="deepseek-v2-lite-16b",
    arch_type="moe",
    n_layers=_L,
    d_model=2048,
    vocab_size=102400,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=10944,
    block_pattern=("attn",) * _L,
    ffn_pattern=("dense",) + ("moe",) * (_L - 1),
    mla=True,
    kv_lora_rank=512,
    q_lora_rank=0,
    qk_rope_head_dim=64,
    qk_nope_head_dim=128,
    v_head_dim=128,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    remat=True,
    source="DeepSeek-V2(-Lite) [arXiv:2405.04434]",
))
