"""RoundDriver — THE warm-up → select → execute → observe → advance-clock
loop (single implementation; benchmarks, tests and the engine all drive
rounds through here instead of re-implementing it).

Three layers:

``CostModel``
    What a device-round costs: ``time_and_bytes(dev, split, clock)`` →
    Eq.-1 wall time + wire bytes, and ``phase_cost(...)`` → the
    upload / server-compute / download decomposition the pipelined
    timeline schedules. ``AnalyticCost`` prices payloads with the
    channel's analytic codec estimates (the benchmark/tests path);
    ``MeteredCost`` uses the exact bytes the ``CommChannel`` metered
    while real tensors crossed it (the ``S2FLEngine`` path); and
    ``FedAvgCost`` prices the full-model baseline. ``CallableCost``
    wraps a plain ``t_of(cid, split)`` for unit tests.

``RoundDriver.run_round``
    One round: during §3.1 warm-up, observe every device's Eq.-1 time so
    the scheduler's client time table fills; select splits; optionally
    call back into the caller (the engine trains for real here and
    returns metered payload bytes + its Eq.-2 groups); observe the
    participants' times; advance the clock.

Execution modes (the clock semantics):
    ``sync``       the paper's Eq.-1 barrier — the round's clock advance
                   is ``max`` over participant times; everything commits
                   in the round it was dispatched.
    ``semi_async`` device/group completions are events in a heap. The
                   aggregation window closes once a ``quorum`` fraction
                   of this round's arrivals are in; stragglers keep
                   running and commit in the window where their event
                   lands, at most ``staleness_cap`` rounds late (the
                   window blocks on any event that would otherwise
                   exceed the cap — ``staleness_cap=0`` degenerates to
                   ``sync``). The clock is a true event timeline: on a
                   static link semi_async wall-clock never exceeds sync
                   (each window closes at or before the sync barrier).

Phase pipeline (``pipeline=True``, orthogonal to the exec mode): each
device-round is split into three chained phase events instead of one
atomic Eq.-1 event —

    upload          Wc dispatch + client forward + features over the
                    uplink (concurrent uploads contend for the shared
                    ingress capacity when the channel bounds it);
    server compute  the group backward — the COMMIT event: windows
                    close, staleness is accounted, and aggregation
                    happens here;
    download        feature gradients + client backward + Wc
                    collection, draining in the background (tracked in
                    a second heap; ``flush()`` waits them out so the
                    final wall-clock is honest).

Because an update commits when its server compute finishes rather than
when its download lands, the server starts one group's backward while
another group's upload is still in flight — with contention and latency
off, every commit can only move earlier, so the pipelined wall-clock is
a lower bound on the phase-sequential one (property-tested in
tests/test_driver_properties.py).

Finite resources (all default off — the free-overlap regime — and all
only observable under the phase pipeline, which is the only timeline
that can see overlap):

    server_concurrency   the Main Server GPU runs at most this many
                         group backwards at once (``_ServerQueue``:
                         FIFO by feature-arrival order; 0 = unbounded);
    downlink_capacity    concurrent dfx downloads contend for the
                         shared egress under the same max-min fair
                         fluid schedule as the uplink (``FluidLink``);
    cross-window carry   uplink AND downlink flows live in stateful
                         ``FluidLink``s that span aggregation windows:
                         a straggler's in-flight transfer slows the
                         next round's cohort, and each round's re-solve
                         revises the straggler's own pending events
                         (already-closed windows can never be
                         disturbed — their inputs all predate every
                         later arrival);
    gate_redispatch      a device must finish draining its own download
                         before its next upload may start (off = the
                         semi-async queue's device-overcommit optimism);
    latency_dist         per-(device, round) latency draws around the
                         mean instead of one shared constant
                         (``links.LatencySampler``, deterministic seed
                         per draw — semi-async replay is exact).

With every knob at its default the event timeline is bit-exact with the
infinite-resource pipeline (closed-form fast paths, golden-tested).

Predictive split selection: with ``predictive=True`` the driver installs
a ``forecast`` hook on the scheduler — instead of trusting the EMA time
table alone, each candidate time is re-priced with the link model's
MEAN rate over the projected completion window ``[clock, clock + ema]``
(``CommChannel.mean_rate`` → ``LinkTrace`` exact integral), so a fade
that will hit mid-round is anticipated rather than discovered. When the
channel bounds the shared uplink, the forecast rate is additionally
capped at ``capacity / round_load`` — the contention-adjusted rate the
device will actually see.

See ``core/README.md`` for the design discussion.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import zlib
from typing import Callable, Optional

from repro.comm.channel import MESSAGES_PER_ROUND
from repro.comm.links import FluidLink
from repro.core.simulation import (BYTES_PER_ELEM, CLIENT_FWD_FRAC,
                                   SERVER_FLOPS, device_round_time_bytes,
                                   fedavg_round_comm_bytes,
                                   fedavg_round_time,
                                   fedavg_round_time_bytes)

EXEC_MODES = ("sync", "semi_async")


def _cid(dev):
    """Device handle -> client id (accepts Device objects or bare ids)."""
    return getattr(dev, "cid", dev)


# ---------------------------------------------------------------------------
# cost models
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PhaseCost:
    """One device-round decomposed for the pipelined timeline.

    Transfer rates are frozen at the dispatch clock (piecewise-constant
    traces make this exact within a segment). The feature upload and
    the dfx download are the segments that contend for the shared
    ingress/egress, so each is kept as (bytes, own-rate) for the fluid
    scheduler; everything else is already seconds. ``t_down`` remains
    the FULL download-phase duration on an uncontended egress (the
    legacy lump, kept verbatim so the default path stays bit-exact);
    ``down_bytes``/``down_rate``/``t_post`` carve the contendable dfx
    transfer out of it for a finite ``downlink_capacity`` (``t_post``:
    the remainder — client backward + Wc collect + latency — that runs
    after the contended transfer lands; None derives it from
    ``t_down``)."""
    t_pre: float           # Wc dispatch transfer + client fwd (+ 2 lat)
    up_bytes: float        # feature payload on the shared uplink
    up_rate: float         # device's own uplink bytes/s at dispatch
    t_srv: float           # server compute (the commit phase)
    t_down: float          # dfx down + client bwd + Wc collect (+ 2 lat)
    total_bytes: float     # full wire traffic (= the atomic accounting)
    down_bytes: float = 0.0        # dfx payload on the shared downlink
    down_rate: float = math.inf    # device's own downlink bytes/s
    t_post: float = None           # post-transfer remainder of t_down

    def post_time(self) -> float:
        """Download-phase time after the contended dfx transfer."""
        if self.t_post is not None:
            return self.t_post
        xfer = self.down_bytes / self.down_rate if self.down_bytes else 0.0
        return self.t_down - xfer


class CostModel:
    """(time, bytes) of one device-round at simulated time ``clock``.

    ``payload_bytes`` / ``dispatch_bytes`` carry exact channel-metered
    cut-layer and model-leg bytes when the caller materialized tensors
    (None -> analytic estimates)."""

    def time_and_bytes(self, dev, split: int, clock: float,
                       payload_bytes: Optional[float] = None,
                       dispatch_bytes: Optional[float] = None):
        raise NotImplementedError

    def phase_cost(self, dev, split: int, clock: float,
                   up_payload: Optional[float] = None,
                   down_payload: Optional[float] = None,
                   disp_down: Optional[float] = None,
                   disp_up: Optional[float] = None
                   ) -> Optional[PhaseCost]:
        """Upload/server/download decomposition for the pipelined
        timeline (None -> no decomposition; the driver falls back to one
        atomic event for this device — e.g. the FedAvg baseline, which
        has no cut layer to pipeline around)."""
        return None

    def shared_uplink_bytes(self) -> float:
        """Shared ingress capacity in bytes/s (inf = uncontended)."""
        return math.inf

    def shared_downlink_bytes(self) -> float:
        """Shared egress capacity in bytes/s (inf = uncontended)."""
        return math.inf

    def forecast_time(self, dev, split: int, clock: float,
                      horizon: float, load: int = 1) -> Optional[float]:
        """Predicted round time if dispatched now and finishing ~horizon
        later (None -> no prediction, caller falls back to the EMA).
        ``load`` is the number of devices expected to share the uplink
        this round (contention-adjusts the forecast rate)."""
        return None


class AnalyticCost(CostModel):
    """Eq.-1 via the channel's analytic payload estimates — what every
    benchmark and scheduler test uses (no tensors ever materialize).

    costs: {split: {'wc_size','feat_size','fc','fs'}} per-sample Eq.-1
    quantities (``repro.utils.flops.split_costs``) or a callable
    ``split -> dict`` (resolved lazily and cached). ``p`` is the local
    sample count per round; ``p_of(cid)`` overrides it per client.
    """

    def __init__(self, channel, costs, *, p: int = 128,
                 p_of: Optional[Callable] = None):
        self.channel = channel
        self._costs = costs if callable(costs) else costs.__getitem__
        self._cache: dict = {}
        self.p_of = p_of or (lambda cid: p)
        # joint batch-size knob (None = off): ``frac_of(cid)`` scales
        # the per-round sample count the Eq.-1 terms price — the driver
        # wires it to the scheduler's ``selected_fracs`` when a joint
        # scheduler is in play
        self.frac_of: Optional[Callable] = None

    def cost(self, split: int) -> dict:
        if split not in self._cache:
            self._cache[split] = self._costs(split)
        return self._cache[split]

    def _p_eff(self, cid):
        """Per-round sample count with the batch-fraction knob applied
        (identical to ``p_of`` while no fraction is selected)."""
        p = self.p_of(cid)
        if self.frac_of is not None:
            f = self.frac_of(cid)
            if f != 1.0:
                p = max(1, int(round(p * f)))
        return p

    def time_and_bytes(self, dev, split, clock, payload_bytes=None,
                       dispatch_bytes=None):
        c, p = self.cost(split), self._p_eff(_cid(dev))
        return self.channel.analytic_round_time(
            dev, wc_size=c["wc_size"], n_values=p * c["feat_size"],
            fc=p * c["fc"], fs=p * c["fs"], t=clock)

    def phase_cost(self, dev, split, clock, up_payload=None,
                   down_payload=None, disp_down=None, disp_up=None):
        c, p = self.cost(split), self._p_eff(_cid(dev))
        ch = self.channel
        rate = ch.rate(dev, clock) * BYTES_PER_ELEM
        n_values = p * c["feat_size"]
        up = (up_payload if up_payload is not None
              else ch.estimate_uplink_payload(n_values))
        down = (down_payload if down_payload is not None
                else ch.estimate_downlink_payload(n_values))
        # one-way model transfers (dispatch codec; fp32 reproduces the
        # seed's wc_size * BYTES_PER_ELEM)
        wc_down = (disp_down if disp_down is not None
                   else ch.estimate_dispatch_leg(c["wc_size"]))
        wc_up = (disp_up if disp_up is not None
                 else ch.estimate_dispatch_leg(c["wc_size"]))
        fc, fs = p * c["fc"], p * c["fs"]
        # half the round's messages ride each client-side phase, so the
        # atomic and phase paths charge the same total latency
        lat2 = 0.5 * MESSAGES_PER_ROUND * ch.latency_of(_cid(dev))
        # t_down keeps the legacy lump arithmetic verbatim (bit-exact
        # default path); t_post carves the dfx transfer out for a
        # contended egress
        return PhaseCost(
            t_pre=lat2 + wc_down / rate
            + CLIENT_FWD_FRAC * fc / dev.comp,
            up_bytes=up, up_rate=rate,
            t_srv=fs / SERVER_FLOPS,
            t_down=lat2 + (down + wc_up) / rate
            + (1.0 - CLIENT_FWD_FRAC) * fc / dev.comp,
            total_bytes=wc_down + wc_up + up + down,
            down_bytes=down, down_rate=rate,
            t_post=lat2 + wc_up / rate
            + (1.0 - CLIENT_FWD_FRAC) * fc / dev.comp)

    def shared_uplink_bytes(self):
        cap = getattr(self.channel, "uplink_capacity", 0.0)
        return cap * BYTES_PER_ELEM if cap else math.inf

    def shared_downlink_bytes(self):
        cap = getattr(self.channel, "downlink_capacity", 0.0)
        return cap * BYTES_PER_ELEM if cap else math.inf

    def forecast_time(self, dev, split, clock, horizon, load=1):
        c, p = self.cost(split), self._p_eff(_cid(dev))
        nbytes = self.channel.estimate_dispatch_round(c["wc_size"]) \
            + self.channel.estimate_round_payload(p * c["feat_size"])
        rate = self.channel.mean_rate(dev, clock,
                                      clock + max(horizon, 1e-9))
        cap = getattr(self.channel, "uplink_capacity", 0.0)
        if cap:
            # contention-adjusted: the shared ingress split across the
            # round's cohort bounds what this device will actually see
            # (even a solo upload is capped at the full ingress, exactly
            # as the fluid schedule caps it)
            rate = min(rate, cap / max(load, 1))
        # forecasts price the MEAN latency (the draw for a future round
        # is unknown; every distribution is mean-preserving)
        return device_round_time_bytes(dev, comm_bytes=nbytes,
                                       fc=p * c["fc"], fs=p * c["fs"],
                                       rate=rate) \
            + MESSAGES_PER_ROUND * self.channel.latency


class MeteredCost(AnalyticCost):
    """Engine path: when the channel metered real payload bytes for a
    participant, price exactly those; otherwise (warm-up observation of
    devices whose tensors never materialize, forecasts) fall back to the
    analytic estimate."""

    def time_and_bytes(self, dev, split, clock, payload_bytes=None,
                       dispatch_bytes=None):
        if payload_bytes is None:
            return super().time_and_bytes(dev, split, clock)
        c, p = self.cost(split), self._p_eff(_cid(dev))
        disp = (dispatch_bytes if dispatch_bytes is not None
                else self.channel.estimate_dispatch_round(c["wc_size"]))
        nbytes = disp + payload_bytes
        t = device_round_time_bytes(
            dev, comm_bytes=nbytes, fc=p * c["fc"], fs=p * c["fs"],
            rate=self.channel.rate(dev, clock)) \
            + MESSAGES_PER_ROUND * self.channel.latency_of(_cid(dev))
        return t, nbytes


class FedAvgCost(CostModel):
    """Full-model FedAvg baseline round cost (split is ignored). No cut
    layer, so there is nothing to phase-split: under ``pipeline=True``
    FedAvg rounds stay atomic events.

    With a ``channel`` the model legs are priced through its dispatch
    codec (the QSGD-style compressed-FedAvg baseline: broadcast down,
    compressed update up); exact metered ``dispatch_bytes`` override
    the analytic estimate when the engine materialized the transfer."""

    def __init__(self, costs_full, *, p: int = 128,
                 p_of: Optional[Callable] = None, channel=None):
        self._costs = costs_full if callable(costs_full) \
            else (lambda: costs_full)
        self._cache = None
        self.p_of = p_of or (lambda cid: p)
        self.channel = channel

    def cost(self) -> dict:
        if self._cache is None:
            self._cache = self._costs()
        return self._cache

    def time_and_bytes(self, dev, split, clock, payload_bytes=None,
                       dispatch_bytes=None):
        c, p = self.cost(), self.p_of(_cid(dev))
        if dispatch_bytes is not None:
            nbytes = dispatch_bytes
        elif self.channel is not None:
            nbytes = self.channel.estimate_dispatch_round(c["w_size"])
        else:
            nbytes = fedavg_round_comm_bytes(w_size=c["w_size"])
        if dispatch_bytes is None and self.channel is None:
            t = fedavg_round_time(dev, w_size=c["w_size"], p=p,
                                  f_full=c["f_full"])
        else:
            rate = (self.channel.rate(dev, clock) if self.channel
                    else None)
            t = fedavg_round_time_bytes(dev, comm_bytes=nbytes, p=p,
                                        f_full=c["f_full"], rate=rate)
        return t, nbytes


class CallableCost(CostModel):
    """Unit-test adapter: a plain ``t_of(cid, split)`` (clock-free) or
    ``t_of(cid, split, clock)`` time function, optional byte function,
    optional ``phases_of(cid, split) -> PhaseCost`` for pipelined
    tests."""

    def __init__(self, t_of: Callable, bytes_of: Optional[Callable] = None,
                 *, clocked: bool = False,
                 phases_of: Optional[Callable] = None):
        self.t_of, self.bytes_of, self.clocked = t_of, bytes_of, clocked
        self.phases_of = phases_of

    def time_and_bytes(self, dev, split, clock, payload_bytes=None,
                       dispatch_bytes=None):
        cid = _cid(dev)
        t = self.t_of(cid, split, clock) if self.clocked \
            else self.t_of(cid, split)
        return t, (self.bytes_of(cid, split) if self.bytes_of else 0.0)

    def phase_cost(self, dev, split, clock, up_payload=None,
                   down_payload=None, disp_down=None, disp_up=None):
        if self.phases_of is None:
            return None
        return self.phases_of(_cid(dev), split)


# ---------------------------------------------------------------------------
# the driver
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class RoundResult:
    round: int                     # round index just driven
    clock: float                   # driver clock after the window closed
    round_time: float              # clock advance this round
    comm_bytes: float              # wire bytes dispatched this round
    splits: dict                   # {cid: split} selected this round
    times: dict                    # {cid: Eq.-1 device time}
    committed: tuple               # work keys whose updates commit now
    staleness: dict                # {key: rounds late} for committed keys
    pending: int                   # commit events still in flight after
    phases: dict = dataclasses.field(default_factory=dict)
    #                              # {cid: {'up','srv','down'} durations}
    #                              # (pipelined rounds only)
    downloads: int = 0             # download events still draining
    abandoned: tuple = ()          # work keys torn down by kills this
    #                              # round (fault injection only) — a
    #                              # dispatched key lands in exactly one
    #                              # of committed/abandoned, ever
    killed: tuple = ()             # cids killed this round
    rejoined: tuple = ()           # cids rejoined before this round


@dataclasses.dataclass(order=True)
class _Event:
    ready: float
    seq: int
    round: int = dataclasses.field(compare=False)
    key: object = dataclasses.field(compare=False)


class _ServerQueue:
    """The Main Server GPU as a finite resource: at most ``slots``
    group backwards run concurrently, FIFO by feature-arrival time
    (ties broken by admission order). Live jobs are re-scheduled from
    scratch by every ``solve()`` — which makes the cross-window
    consistency argument simple: a schedule whose arrivals did not
    change recomputes to the bit-identical finishes, while pending
    jobs whose uplink flows were slowed by a later cohort shift (and
    may reorder) behind it. ``compact()`` retires jobs that can no
    longer interact with anything schedulable (same prefix rule as
    ``FluidLink``: all slots they occupied are free before every kept
    job's arrival), bounding the per-round cost by the jobs still in
    flight."""

    def __init__(self, slots: float = math.inf):
        if slots != math.inf and slots < 1:
            raise ValueError(f"server slots must be >= 1 (or inf): {slots}")
        self.slots = slots
        self._arrive: list = []
        self._dur: list = []
        self._live: list = []          # jids still in the schedule
        self._finish_cache: dict = {}  # retired jid -> finish

    def add(self, arrival: float, duration: float) -> int:
        self._arrive.append(float(arrival))
        self._dur.append(float(duration))
        self._live.append(len(self._arrive) - 1)
        return len(self._arrive) - 1

    def set_arrival(self, jid: int, arrival: float):
        self._arrive[jid] = float(arrival)

    def solve(self):
        """Finish time per job (index = jid; retired jobs from cache)."""
        finish = [0.0] * len(self._arrive)
        for j, fin in self._finish_cache.items():
            finish[j] = fin
        if math.isinf(self.slots):
            for i in self._live:
                finish[i] = self._arrive[i] + self._dur[i]
            return finish
        order = sorted(self._live, key=lambda i: (self._arrive[i], i))
        free = [0.0] * int(self.slots)   # slot free times (min-heap)
        for i in order:
            start = max(self._arrive[i], heapq.heappop(free))
            finish[i] = start + self._dur[i]
            heapq.heappush(free, finish[i])
        return finish

    def compact(self, now: float):
        from repro.comm.links import retire_prefix
        if len(self._live) <= 1:
            return
        fins = self.solve()
        retired, kept = retire_prefix(self._live, fins, self._arrive, now)
        if retired:
            for j in retired:
                self._finish_cache[j] = fins[j]
            self._live = kept

    def cancel(self, jid: int, t: float) -> bool:
        """Tear down job ``jid`` at time ``t`` (its device died). A job
        still WAITING at ``t`` leaves the queue entirely (its FIFO
        position frees for the jobs behind it); a RUNNING job has its
        duration truncated so its slot frees at the kill instant — the
        schedule before ``t`` is history and stays untouched. A job
        already finished (or retired) is a no-op. Returns True when the
        job was actually cancelled."""
        if jid in self._finish_cache:
            return False
        fins = self.solve()
        if fins[jid] <= t:
            return False               # finished before the kill
        start = fins[jid] - self._dur[jid]
        if start >= t:
            # never started: drop it from the schedule outright
            self._live.remove(jid)
            self._finish_cache[jid] = t
            return True
        self._dur[jid] = t - start
        return True

    def depth_at(self, t: float) -> int:
        """Jobs arrived but unfinished at ``t`` (waiting + running) —
        the queue-depth gauge the TraceRecorder samples. Observational
        only: re-uses ``solve()``, never mutates the schedule."""
        fins = self.solve()
        return sum(1 for i in self._live
                   if self._arrive[i] <= t < fins[i])

    # ------------------------------------------------ checkpoint state
    def export_state(self) -> dict:
        return {"slots": self.slots,
                "arrive": list(self._arrive),
                "dur": list(self._dur),
                "live": list(self._live),
                "finish_cache": [[j, fin] for j, fin
                                 in sorted(self._finish_cache.items())]}

    @classmethod
    def from_state(cls, st: dict) -> "_ServerQueue":
        q = cls(st["slots"])
        q._arrive = [float(x) for x in st["arrive"]]
        q._dur = [float(x) for x in st["dur"]]
        q._live = [int(j) for j in st["live"]]
        q._finish_cache = {int(j): float(fin)
                           for j, fin in st["finish_cache"]}
        return q


@dataclasses.dataclass
class _Flight:
    """One pipelined device-round in flight: its uplink flow, server
    job and (when the egress is contended) downlink flow ids, plus the
    latest solved commit / download-end estimates. Flights persist
    across rounds until their commit event has been popped AND their
    download has drained, so each round's resource re-solve can push a
    straggler's pending events later."""
    uid: int
    cid: object
    round: int
    fid: int                   # uplink FluidLink flow id
    jid: int                   # _ServerQueue job id
    pc: PhaseCost
    did: Optional[int] = None  # downlink flow id (contended egress only)
    key: object = None         # commit work-item (group) key
    commit: float = math.nan
    dl_end: float = math.nan
    dispatch: float = 0.0      # phase start (dispatch clock + gate wait)
    up_end: float = math.nan   # latest solved uplink-flow finish


class RoundDriver:
    """Owns the round loop and the simulated timeline.

    scheduler : Sliding/MinTime/FixedSplitScheduler (select/observe/
                end_round + the §3.1 warm-up protocol)
    cost      : a CostModel
    devices   : Device objects (or bare cids with a CallableCost)
    warmup_devices : subset observed during warm-up rounds (default: all
                devices — the engine restricts to devices that own data)
    pipeline  : phase-level event timeline (upload / server-compute /
                download) instead of one atomic event per device-round
    server_concurrency : max concurrent group backwards on the Main
                Server GPU (0 = unbounded; pipeline only)
    gate_redispatch : a device's next upload waits out its own draining
                download (off = device-overcommit optimism; pipeline
                only)
    recorder  : an ``observe.TraceRecorder`` (None or the no-op default
                = zero overhead: every hook site guards on
                ``recorder.enabled`` before building any record)
    fleet     : a ``core.fleet.Fleet`` batched population — devices may
                then be empty; cohort members' Device objects
                materialize lazily (O(active cohort), never O(P))
    clusters / cluster_quorum : hierarchical aggregation (devices →
                edge clusters → main server): each cluster closes at
                its own ``cluster_quorum`` quantile, the global window
                at ``quorum`` over the cluster close times; clusters
                <= 1 is the flat window, bit-for-bit
    """

    def __init__(self, scheduler, cost: CostModel, devices, *,
                 mode: str = "sync", staleness_cap: int = 1,
                 quorum: float = 0.5, predictive: bool = False,
                 resource_aware: bool = False,
                 pipeline: bool = False, warmup_devices=None,
                 server_concurrency: int = 0,
                 gate_redispatch: bool = False, recorder=None,
                 fault_plan=None, knob_controller=None,
                 fleet=None, clusters: int = 0,
                 cluster_quorum: float = 1.0):
        if mode not in EXEC_MODES:
            raise ValueError(f"exec mode {mode!r}; known: {EXEC_MODES}")
        if staleness_cap < 0:
            raise ValueError(f"staleness_cap must be >= 0: {staleness_cap}")
        if not 0.0 < quorum <= 1.0:
            raise ValueError(f"quorum must be in (0, 1]: {quorum}")
        if not 0.0 < cluster_quorum <= 1.0:
            raise ValueError(
                f"cluster_quorum must be in (0, 1]: {cluster_quorum}")
        if clusters < 0:
            raise ValueError(f"clusters must be >= 0: {clusters}")
        if server_concurrency < 0:
            raise ValueError(f"server_concurrency must be >= 0 "
                             f"(0 = unbounded): {server_concurrency}")
        self.scheduler = scheduler
        self.cost = cost
        self.devices = list(devices)
        self.warmup_devices = (list(warmup_devices)
                               if warmup_devices is not None
                               else self.devices)
        self._dev_by_id = {_cid(d): d for d in self.devices}
        # batched population (core/fleet.py): Device objects materialize
        # lazily through _dev_of, only for sampled cids — the driver
        # never walks the full population
        self._fleet = fleet
        self.clusters = int(clusters)
        if fleet is not None:
            if self.clusters == 0:
                self.clusters = int(getattr(fleet, "clusters", 0))
            elif getattr(fleet, "clusters", 0) != self.clusters:
                # one source of truth for the topology: the driver's
                # explicit knob wins and the fleet's mapping follows
                fleet.clusters = self.clusters
        self.cluster_quorum = float(cluster_quorum)
        self.mode = mode
        self.staleness_cap = staleness_cap
        self.quorum = quorum
        self.pipeline = bool(pipeline)
        self.server_concurrency = int(server_concurrency)
        self.gate_redispatch = bool(gate_redispatch)
        self.recorder = recorder
        self.clock = 0.0
        self.comm = 0.0                 # accumulated wire bytes
        self.round = 0
        self._pending: list = []        # _Event heap (commit events)
        self._downloads: list = []      # (ready, uid) heap (pipeline)
        self._seq = 0
        self._load = 1                  # current round's cohort size
        # pipeline resource state (built lazily on the first pipelined
        # round so the cost model's capacities are settled)
        self._uplink: Optional[FluidLink] = None
        self._downlink: Optional[FluidLink] = None
        self._srvq: Optional[_ServerQueue] = None
        self._flights: dict = {}        # uid -> _Flight (live)
        self._next_uid = 0
        self._dev_busy: dict = {}       # cid -> latest own download end
        self._round_uids: dict = {}     # this round's cid -> flight uid
        # fault injection (core/faults.py; None = the no-churn world,
        # bit-exact with the pre-fault driver)
        self.fault_plan = fault_plan
        self._dead: dict = {}           # cid -> round it was killed
        self._incarnation: dict = {}    # cid -> rejoin count (identity)
        self._members: dict = {}        # (round, key) -> {cid: commit}
        self._abandoned_ids: set = set()   # (round, key) torn down
        self._abandoned_now: list = []  # keys abandoned this run_round
        self.n_dispatched = 0           # work items pushed, ever
        self.n_committed = 0            # work items popped & committed
        self.n_abandoned = 0            # work items torn down by kills
        # resource-aware control plane (core/control.py): the scheduler
        # prices candidates against the LIVE queue/link/residual state
        # through a read-only ResourceView, with the forecast horizon
        # learned from the observed round-time distribution
        self.resource_aware = bool(resource_aware)
        self._history = None
        self._last_split: dict = {}
        self.view = None
        if resource_aware:
            from repro.core.control import ResourceView
            from repro.observe.history import RoundTimeTracker
            self._history = RoundTimeTracker()
            self.view = ResourceView(self, self._history)
        self.knob_controller = knob_controller
        if predictive or resource_aware:
            if not hasattr(scheduler, "forecast"):
                raise ValueError(
                    f"{type(scheduler).__name__} has no forecast hook; "
                    "predictive/resource-aware mode needs a sliding "
                    "scheduler")
            scheduler.forecast = self._forecast
            if resource_aware and hasattr(scheduler, "forecast_frac"):
                # joint batch-size knob: the scheduler can price
                # (split, frac) pairs through the same physics
                scheduler.forecast_frac = (
                    lambda cid, split, rec, frac:
                    self._forecast(cid, split, rec, frac=frac))
        # joint-knob consumers: the cost model prices each round with
        # the scheduler's selected batch fractions (engine-owned cost
        # models pre-install their own hook and are left alone)
        if (getattr(scheduler, "selected_fracs", None) is not None
                and getattr(cost, "frac_of", False) is None):
            cost.frac_of = (lambda cid:
                            scheduler.selected_fracs.get(cid, 1.0))

    # ------------------------------------------------------------ fleet
    def _dev_of(self, cid):
        """Device for ``cid`` — from the object grid, else materialized
        lazily from the fleet tables (cached so a returning cohort
        member costs one dict hit). None when neither knows the cid."""
        dev = self._dev_by_id.get(cid)
        if dev is None and self._fleet is not None:
            try:
                dev = self._fleet.device(cid)
            except (IndexError, TypeError, ValueError):
                return None
            self._dev_by_id[cid] = dev
        return dev

    def _cluster_of(self, cid):
        """Edge-cluster assignment for hierarchical aggregation."""
        if self._fleet is not None:
            return self._fleet.cluster_of(cid)
        try:
            return int(cid) % self.clusters
        except (TypeError, ValueError):
            return zlib.crc32(str(cid).encode("utf8")) % self.clusters

    # -------------------------------------------------------- predictive
    def _forecast(self, cid, split, recorded, frac=1.0):
        """Scheduler hook. Blind predictive mode re-prices the EMA entry
        with the link's mean rate over the projected completion window
        [clock, clock+ema], contention-adjusted by the round's cohort
        size. Resource-aware mode instead prices the candidate against
        the live driver state (queue depth, link backlog, own draining
        download, residual mass, learned horizon band) — falling back
        to the blind path for cost models with no analytic surface."""
        dev = self._dev_of(cid)
        if dev is None:
            return None
        if self.resource_aware:
            from repro.core.control import resource_aware_forecast
            ft = resource_aware_forecast(self.view, self.cost, dev,
                                         split, recorded, frac=frac)
            if ft is not None:
                return ft
        return self.cost.forecast_time(dev, split, self.clock, recorded,
                                       load=self._load)

    def _apply_knobs(self):
        """Adopt the aggregation controller's current (quorum,
        staleness_cap) at a window boundary. Safety rule: the cap never
        drops below the age of the oldest pending event, so every
        commit this window still satisfies the staleness invariant
        (re-evaluated each round — the requested cap takes over once
        the old stragglers drain)."""
        q, cap = self.knob_controller.current()
        max_age = max((self.round - e.round for e in self._pending),
                      default=0)
        self.quorum = q
        self.staleness_cap = max(int(cap), max_age)

    # ------------------------------------------------------------- round
    def run_round(self, participants, execute=None) -> RoundResult:
        """Drive one round. ``participants``: cids or Device objects.

        ``execute(splits) -> report`` (optional) runs the caller's real
        work after selection; the report dict may carry
        ``payload_bytes`` ({cid: metered wire bytes, cut-layer only}),
        ``payload_up_bytes`` / ``payload_down_bytes`` (the per-direction
        split the pipelined timeline prices), ``dispatch_bytes``
        ({cid: metered model-leg bytes, dispatch + collect} with the
        per-direction ``dispatch_down_bytes`` / ``dispatch_up_bytes``)
        and ``groups`` ({work_key: (cid, ...)} — commit granularity;
        default one work item per participant keyed by cid).
        """
        part = [_cid(p) for p in participants]
        clock0 = self.clock
        if self.knob_controller is not None:
            self._apply_knobs()
        # fault plan: rejoins + pre-dispatch kills land before selection
        # (a dead device is filtered from the cohort; its carried
        # straggler work is torn down at the current clock); mid-flight
        # kills are held until this round's dispatch times are solved
        self._abandoned_now = []
        mid_kills, killed, rejoined = [], [], []
        if self.fault_plan is not None:
            for e in self.fault_plan.for_round(self.round):
                if e.kind == "rejoin":
                    if self._rejoin(e.cid):
                        rejoined.append(e.cid)
                elif e.at is None:
                    if self._kill(e.cid, clock0):
                        killed.append(e.cid)
                else:
                    mid_kills.append(e)
            part = [c for c in part if c not in self._dead]
        part_set = set(part)
        self._load = max(1, len(part))
        # per-(device, round) latency draws key on the round index
        ch = getattr(self.cost, "channel", None)
        if ch is not None:
            ch.sim_round = self.round

        # §3.1 warm-up: the shared split is dispatched to ALL devices so
        # the whole client time table fills; participants are observed
        # below with their (possibly metered) round times instead.
        if getattr(self.scheduler, "warming_up", False):
            s = self.scheduler.warmup_split()
            for d in self.warmup_devices:
                if _cid(d) in part_set or _cid(d) in self._dead:
                    continue
                t, _ = self.cost.time_and_bytes(d, s, clock0)
                self.scheduler.observe(_cid(d), s, t)

        splits = self.scheduler.select(part)
        plan = getattr(self.scheduler, "plan", None)
        if plan is not None:
            assert all(splits[c] in plan for c in part), splits

        report = execute(splits) if execute is not None else None
        payloads = (report or {}).get("payload_bytes", {})
        pay_up = (report or {}).get("payload_up_bytes", {})
        pay_down = (report or {}).get("payload_down_bytes", {})
        dispatch = (report or {}).get("dispatch_bytes", {})
        disp_down = (report or {}).get("dispatch_down_bytes", {})
        disp_up = (report or {}).get("dispatch_up_bytes", {})
        groups = (report or {}).get("groups")
        if groups is None:
            groups = {c: (c,) for c in part}

        phases: dict = {}
        if self.pipeline:
            commits, times, comm, phases = self._phase_schedule(
                part, splits, payloads, pay_up, pay_down,
                disp_down, disp_up, clock0)
        else:
            times, comm = {}, 0.0
            for c in part:
                dev = self._dev_of(c) or c
                t, nbytes = self.cost.time_and_bytes(
                    dev, splits[c], clock0,
                    payload_bytes=payloads.get(c),
                    dispatch_bytes=dispatch.get(c))
                times[c] = t
                comm += nbytes
            commits = {c: clock0 + times[c] for c in part}
        for c in part:
            self.scheduler.observe(c, splits[c], times[c])
        if self._history is not None:
            # the control plane's learned horizon: observed (not
            # forecast) per-device round times, and the split each
            # device last ran — what the residual-aware re-split
            # penalty compares candidates against
            for c in part:
                self._history.observe(c, times[c])
                self._last_split[c] = splits[c]

        items = {key: max(commits[c] for c in members)
                 for key, members in groups.items() if members}
        if self.pipeline and self._round_uids:
            # commit-granularity backref: carried flights re-key their
            # group's pending event on later rounds' resource re-solves
            for key, members in groups.items():
                for c in members:
                    uid = self._round_uids.get(c)
                    if uid is not None:
                        self._flights[uid].key = key

        # exactly-once ledger: every fresh work item is dispatched ONCE
        # here and will land in committed or abandoned, never both,
        # never twice (commits pop it from the heap; kills remove it
        # and record its (dispatch-round, key) identity)
        for key, ready in items.items():
            self._push(key, ready)
        self.n_dispatched += len(items)
        for key, members in groups.items():
            if members:
                self._members[(self.round, key)] = {c: commits[c]
                                                   for c in members}

        # mid-flight kills: the kill instant interpolates between the
        # dispatch clock and the round's last fresh commit estimate, so
        # the device dies while its transfers/backwards are in flight
        if mid_kills:
            horizon = max(items.values()) if items else clock0
            for e in mid_kills:
                t_kill = clock0 + e.at * max(horizon - clock0, 0.0)
                if self._kill(e.cid, t_kill):
                    killed.append(e.cid)

        fresh = [(r, self._item_cluster(groups.get(key) or (key,)))
                 for key, r in items.items()
                 if (self.round, key) not in self._abandoned_ids]
        committed, staleness, new_clock = self._close_window(fresh, clock0)
        self._drain_downloads(new_clock)

        self.clock = new_clock
        self.comm += comm
        if (self._fleet is not None and ch is not None
                and hasattr(ch, "residual_elements_of")):
            # fold the cohort's EF residual mass back into the (P,)
            # population table — O(active cohort), and the only write
            # the fleet sees from the round loop
            for c in part:
                self._fleet.note_residual(c, ch.residual_elements_of(c))
        if self.knob_controller is not None:
            self.knob_controller.observe(new_clock - clock0)
        self.scheduler.end_round()
        if self.recorder is not None and self.recorder.enabled:
            self._observe_round(groups, commits, clock0, committed,
                                staleness, new_clock)
        rec = RoundResult(
            round=self.round, clock=self.clock,
            round_time=new_clock - clock0, comm_bytes=comm, splits=splits,
            times=times, committed=tuple(committed), staleness=staleness,
            pending=len(self._pending), phases=phases,
            downloads=len(self._downloads),
            abandoned=tuple(self._abandoned_now),
            killed=tuple(killed), rejoined=tuple(rejoined))
        self.round += 1
        self._prune_flights()
        # member maps are only needed while their event pends
        live = {(e.round, e.key) for e in self._pending}
        self._members = {k: v for k, v in self._members.items()
                         if k in live}
        return rec

    # ----------------------------------------------------- observability
    def _observe_round(self, groups, commits, clock0, committed,
                       staleness, new_clock):
        """Feed the injected TraceRecorder after the window closed:
        upsert every live flight's span estimates (the same
        latest-wins semantics as the driver's own ``_Flight``
        revisions — once a flight's window has closed its record is
        final), record atomic lumps for work not phase-decomposed, the
        window itself, and the round's gauges. Only reached when a
        recording recorder is injected; the default path never builds
        any of this."""
        rec = self.recorder
        for fl in self._flights.values():
            pc = fl.pc
            rec.flight(fl.uid, cid=fl.cid, round=fl.round, key=fl.key,
                       dispatch=fl.dispatch, t_pre=pc.t_pre,
                       up_start=fl.dispatch + pc.t_pre,
                       up_bytes=pc.up_bytes, up_rate=pc.up_rate,
                       up_end=fl.up_end,
                       srv_start=fl.commit - pc.t_srv,
                       srv_end=fl.commit,
                       dl_xfer_end=fl.dl_end - pc.post_time(),
                       dl_end=fl.dl_end)
        flight_cids = set(self._round_uids) if self.pipeline else set()
        for key, members in groups.items():
            atoms = [c for c in members if c not in flight_cids]
            if atoms:
                rec.atomic(key, self.round, atoms, clock0,
                           max(commits[c] for c in atoms))
        rec.window(self.round, clock0, new_clock, staleness,
                   len(self._pending))
        rec.count("driver.rounds")
        rec.count("driver.commits", len(committed))
        rec.gauge("window.staleness.max", new_clock,
                  max(staleness.values(), default=0))
        rec.gauge("window.pending", new_clock, len(self._pending))
        if self._srvq is not None:
            rec.gauge("server.queue_depth", new_clock,
                      self._srvq.depth_at(new_clock))
            rec.gauge("downloads.in_flight", new_clock,
                      len(self._downloads))
            for name, link in (("uplink", self._uplink),
                               ("downlink", self._downlink)):
                rec.gauge(f"{name}.live_flows", new_clock,
                          len(link._live))
                rec.gauge(f"{name}.solves", new_clock, link.n_solves)
                rec.gauge(f"{name}.retired", new_clock, link.n_retired)
                if link.contended and new_clock > clock0:
                    rec.gauge(f"{name}.utilization", new_clock,
                              link.utilization(clock0, new_clock))
        ch = getattr(self.cost, "channel", None)
        if ch is not None and getattr(ch, "error_feedback", False):
            rec.gauge("channel.ef_residual", new_clock,
                      ch.residual_norm())

    # --------------------------------------------------- phase pipeline
    def _phase_schedule(self, part, splits, payloads, pay_up, pay_down,
                        disp_down, disp_up, clock0):
        """Chain upload → server-compute → download through the shared
        finite resources. Returns ({cid: commit time}, {cid: full round
        duration}, round wire bytes, {cid: phase durations}).

        Commit = the end of the device's server-compute share — its own
        Eq.-1 Fs term, queued FIFO on the server's `server_concurrency`
        slots (unbounded by default), chained on its own upload through
        the shared-ingress fluid schedule. Downloads cross the shared
        egress and drain in the background: they gate ``flush()``, the
        honest final wall-clock, and (with ``gate_redispatch``) the
        device's own next dispatch — never the aggregation windows.

        All three resources are STATEFUL across aggregation windows:
        flows and jobs live until they finish, and each round re-solves
        over everything still in flight, which both (a) slows this
        cohort by the straggler transfers it overlaps and (b) revises
        the stragglers' own pending commit/download events (the re-key
        step below). Fluid-link finishes only ever move later (extra
        demand cannot speed a transfer up); a finite-slot server queue
        can also move a pending commit EARLIER when a delayed upload
        vacates its FIFO position — both directions are corrections of
        an optimistic pending estimate, never of history: an event that
        already closed a window had every input in the past of every
        later arrival, so no re-solve can disturb the committed
        timeline, and a pending event revised below the current clock
        simply commits in the next window (the staleness forcing still
        bounds its lag)."""
        if self._uplink is None:
            self._uplink = FluidLink(self.cost.shared_uplink_bytes())
            self._downlink = FluidLink(self.cost.shared_downlink_bytes())
            self._srvq = _ServerQueue(self.server_concurrency or math.inf)
        else:
            # retire finished history that can no longer interact with
            # anything schedulable (every new arrival is >= clock0), so
            # the re-solves below cost O(in-flight), not O(all rounds)
            self._uplink.compact(clock0)
            self._downlink.compact(clock0)
            self._srvq.compact(clock0)

        quants = {}
        for c in part:
            dev = self._dev_of(c) or c
            quants[c] = self.cost.phase_cost(
                dev, splits[c], clock0, up_payload=pay_up.get(c),
                down_payload=pay_down.get(c),
                disp_down=disp_down.get(c), disp_up=disp_up.get(c))

        commits, times, phases, comm = {}, {}, {}, 0.0
        self._round_uids = {}
        for c, pc in quants.items():
            if pc is None:             # no decomposition: atomic event
                dev = self._dev_of(c) or c
                disp = (disp_down.get(c, 0.0) + disp_up.get(c, 0.0)
                        if c in disp_down or c in disp_up else None)
                t, nbytes = self.cost.time_and_bytes(
                    dev, splits[c], clock0,
                    payload_bytes=payloads.get(c), dispatch_bytes=disp)
                commits[c] = clock0 + t
                times[c] = t
                comm += nbytes
                continue
            start = clock0
            if self.gate_redispatch:
                start = max(start, self._dev_busy.get(c, 0.0))
            fid = self._uplink.submit(start + pc.t_pre, pc.up_bytes,
                                      pc.up_rate)
            jid = self._srvq.add(math.inf, pc.t_srv)
            fl = _Flight(uid=self._next_uid, cid=c, round=self.round,
                         fid=fid, jid=jid, pc=pc, dispatch=start)
            self._next_uid += 1
            self._flights[fl.uid] = fl
            self._round_uids[c] = fl.uid
            comm += pc.total_bytes

        # one re-solve over everything still in flight: ingress fluid
        # schedule → server FIFO queue → egress fluid schedule
        up_fin = self._uplink.solve()
        for fl in self._flights.values():
            fl.up_end = up_fin[fl.fid]
            self._srvq.set_arrival(fl.jid, up_fin[fl.fid])
        srv_fin = self._srvq.solve()
        for fl in self._flights.values():
            fl.commit = srv_fin[fl.jid]
            if self._downlink.contended and fl.pc.down_bytes:
                if fl.did is None:
                    fl.did = self._downlink.submit(
                        fl.commit, fl.pc.down_bytes, fl.pc.down_rate)
                else:
                    self._downlink.set_arrival(fl.did, fl.commit)
        dn_fin = self._downlink.solve() if self._downlink.contended \
            else None
        for fl in self._flights.values():
            if fl.did is not None:
                fl.dl_end = dn_fin[fl.did] + fl.pc.post_time()
            else:
                # uncontended egress: the legacy closed form, bit-exact
                fl.dl_end = fl.commit + fl.pc.t_down
            busy = self._dev_busy.get(fl.cid, 0.0)
            self._dev_busy[fl.cid] = max(busy, fl.dl_end)

        # carried flights: the re-solve may have revised a straggler's
        # commit — re-key its pending event. Keyed by (dispatch round,
        # work key): the default standalone work keys are bare device
        # cids, which REPEAT when a device is re-dispatched while its
        # old event still pends, and the two dispatches must not feed
        # each other's ready times.
        if self._pending:
            by_key: dict = {}
            for fl in self._flights.values():
                if fl.key is not None:
                    by_key.setdefault((fl.round, fl.key), []).append(fl)
            moved = False
            for e in self._pending:
                fls = by_key.get((e.round, e.key))
                if fls:
                    ready = max(fl.commit for fl in fls)
                    if ready != e.ready:
                        e.ready = ready
                        moved = True
            if moved:
                heapq.heapify(self._pending)

        # this cohort's view: the scheduler observes times, the history
        # carries the phase split
        for c, uid in self._round_uids.items():
            fl = self._flights[uid]
            commits[c] = fl.commit
            times[c] = fl.dl_end - clock0
            phases[c] = {"up": up_fin[fl.fid] - clock0,
                         "srv": fl.commit - up_fin[fl.fid],
                         "down": fl.dl_end - fl.commit}

        # the download heap mirrors the latest estimate for every live
        # flight (every one ends after this round's dispatch clock —
        # drained flights were pruned when their window closed)
        self._downloads = [(fl.dl_end, fl.uid)
                           for fl in self._flights.values()]
        heapq.heapify(self._downloads)
        return commits, times, comm, phases

    def _drain_downloads(self, horizon):
        while self._downloads and self._downloads[0][0] <= horizon:
            heapq.heappop(self._downloads)

    def _prune_flights(self):
        """Drop flights whose commit event has been popped AND whose
        download has drained (their resource jobs stay behind in the
        links/queue until compaction retires them). Matched by
        (dispatch round, work key) — a re-dispatched device reuses its
        bare-cid key, and its drained earlier flight must not be kept
        alive by the new dispatch's pending event."""
        if not self._flights:
            return
        pending = {(e.round, e.key) for e in self._pending}
        gone = [u for u, fl in self._flights.items()
                if (fl.round, fl.key) not in pending
                and fl.dl_end <= self.clock]
        for u in gone:
            del self._flights[u]

    # ------------------------------------------------------ event window
    def _push(self, key, ready):
        heapq.heappush(self._pending,
                       _Event(ready, self._seq, self.round, key))
        self._seq += 1

    def _pop_ready(self, horizon):
        out = []
        while self._pending and self._pending[0].ready <= horizon:
            out.append(heapq.heappop(self._pending))
        return out

    def _item_cluster(self, members) -> int:
        """Edge cluster of a work item = its first member's cluster
        (groups are cluster-pure under the engine's fleet grouping;
        mixed groups inherit the first member's edge)."""
        if self.clusters <= 1:
            return 0
        return self._cluster_of(next(iter(members)))

    def _close_window(self, fresh_items, now: float):
        """``fresh_items``: (ready time, cluster) pairs for this round's
        surviving work items (their events are already in the heap —
        kills may have removed some before the window closes). Returns
        (committed keys, staleness per key in rounds, new clock).

        With ``clusters > 1`` the quorum is hierarchical: each edge
        cluster closes at its own ``cluster_quorum`` quantile over its
        members' ready times, then the main server closes at the
        ``quorum`` quantile over the *cluster* close times — the
        ParallelSFL two-level formulation. ``clusters <= 1`` reproduces
        the flat window bit-for-bit, and so does one-device-per-cluster
        (each cluster time degenerates to its single ready time)."""
        if self.mode == "sync" or self.staleness_cap == 0:
            # barrier: everything dispatched must land this round
            new_clock = max((e.ready for e in self._pending), default=now)
        elif not self._pending:
            return [], {}, now
        else:
            t_quorum = self._quorum_time(fresh_items, now)
            # any event that would exceed the staleness cap by waiting
            # for the NEXT window must be waited for in this one
            forced = [e.ready for e in self._pending
                      if e.round <= self.round - self.staleness_cap]
            new_clock = max([t_quorum, now] + forced)
        done = self._pop_ready(new_clock)
        self.n_committed += len(done)
        committed = [e.key for e in done]
        staleness = {e.key: self.round - e.round for e in done}
        assert all(v <= max(self.staleness_cap, 0)
                   for v in staleness.values()), staleness
        return committed, staleness, new_clock

    def _quorum_time(self, fresh_items, now: float) -> float:
        """Quorum close time over this round's fresh items — flat
        quantile, or the two-level cluster form when clusters > 1."""
        if not fresh_items:
            return now
        if self.clusters > 1:
            by_cluster: dict = {}
            for ready, cl in fresh_items:
                by_cluster.setdefault(cl, []).append(ready)
            t_clusters = []
            for cl in sorted(by_cluster):
                rs = sorted(by_cluster[cl])
                qc = max(1, math.ceil(self.cluster_quorum * len(rs)))
                t_clusters.append(rs[qc - 1])
            t_clusters.sort()
            q = max(1, math.ceil(self.quorum * len(t_clusters)))
            return t_clusters[q - 1]
        readies = sorted(r for r, _ in fresh_items)
        q = max(1, math.ceil(self.quorum * len(readies)))
        return readies[q - 1]

    # --------------------------------------------------- fault injection
    def _kill(self, cid, t: float) -> bool:
        """Device ``cid`` dies at simulated time ``t``: its in-flight
        link flows are abandoned (capacity released at the kill instant,
        survivor schedules before ``t`` untouched), its server work is
        cancelled or orphaned per the plan's ``server_policy``, its
        error-feedback residuals are quarantined on the channel, and
        every pending work item whose dead member had NOT delivered its
        contribution by ``t`` is abandoned — recorded under its
        (dispatch-round, work-key) identity so it can never commit.
        Returns False when the device was already dead (no-op)."""
        if cid in self._dead:
            return False
        self._dead[cid] = self.round
        policy = (self.fault_plan.server_policy
                  if self.fault_plan is not None else "cancel")
        # 1. tear down the device's in-flight resources (pipeline only)
        doomed_fl = [fl for fl in self._flights.values() if fl.cid == cid]
        flight_commit = {}
        for fl in doomed_fl:
            flight_commit[(fl.round, fl.key)] = fl.commit
            up_done = not math.isnan(fl.up_end) and fl.up_end <= t
            self._uplink.abandon(fl.fid, t)
            if not up_done or policy == "cancel":
                # the features never fully arrived, or the policy frees
                # the slot: the job leaves the queue / truncates at t.
                # 'orphan' with a fed job lets the backward run to
                # completion occupying its slot — the result is dropped
                # with the flight either way.
                self._srvq.cancel(fl.jid, t)
            if fl.did is not None:
                self._downlink.abandon(fl.did, t)
            del self._flights[fl.uid]
        if doomed_fl:
            # the download heap must forget the dead device NOW so a
            # same-round flush doesn't wait on an abandoned download
            self._downloads = [(fl.dl_end, fl.uid)
                               for fl in self._flights.values()]
            heapq.heapify(self._downloads)
        # 2. abandon pending work the dead member never delivered: its
        # own commit (live-flight estimate, else the dispatch record)
        # past the kill instant means its gradient contribution was
        # still in flight when it died
        doomed_ev = []
        for e in self._pending:
            mem = self._members.get((e.round, e.key))
            if mem is None or cid not in mem:
                continue
            own = flight_commit.get((e.round, e.key), mem.get(cid))
            if own is None or math.isnan(own) or own > t:
                doomed_ev.append(e)
        if doomed_ev:
            for e in doomed_ev:
                self._pending.remove(e)
                self._abandoned_ids.add((e.round, e.key))
                self._abandoned_now.append(e.key)
            self.n_abandoned += len(doomed_ev)
            heapq.heapify(self._pending)
        # 3. quarantine the device's error-feedback residuals until it
        # rejoins (restored or discarded there, per residual_policy)
        ch = getattr(self.cost, "channel", None)
        if ch is not None and hasattr(ch, "quarantine_residuals"):
            ch.quarantine_residuals(cid)
        if self.recorder is not None and self.recorder.enabled:
            self.recorder.count("driver.kills")
            self.recorder.count("driver.abandons", len(doomed_ev))
        return True

    def _rejoin(self, cid) -> bool:
        """Device ``cid`` comes back before this round's dispatch under
        a FRESH identity: its incarnation counter bumps (a later
        dispatch gets a new (round, key) identity, so nothing stale can
        double-count), its re-dispatch gate resets, and its quarantined
        residuals are restored or discarded per ``residual_policy``.
        Returns False when the device was not dead (no-op)."""
        if cid not in self._dead:
            return False
        del self._dead[cid]
        self._incarnation[cid] = self._incarnation.get(cid, 0) + 1
        self._dev_busy.pop(cid, None)
        ch = getattr(self.cost, "channel", None)
        if ch is not None and hasattr(ch, "release_residuals"):
            restore = (self.fault_plan is None
                       or self.fault_plan.residual_policy == "restore")
            ch.release_residuals(cid, restore=restore)
        if self.recorder is not None and self.recorder.enabled:
            self.recorder.count("driver.rejoins")
        return True

    def flush(self):
        """Wait out every in-flight event (end of training): advances the
        clock past the last pending commit AND the last draining
        download, commits everything. Returns (committed keys, staleness
        dict)."""
        ready = [e.ready for e in self._pending] \
            + [r for r, *_ in self._downloads]
        if not ready:
            return [], {}
        clock0 = self.clock
        new_clock = max(ready)
        done = self._pop_ready(new_clock)
        self.n_committed += len(done)
        self._drain_downloads(new_clock)
        self.clock = max(self.clock, new_clock)
        staleness = {e.key: self.round - 1 - e.round for e in done}
        if self.recorder is not None and self.recorder.enabled:
            # flight spans were already (finally) recorded by the last
            # round's sweep — flush adds no re-solve, only the drain
            # window itself
            self.recorder.window(self.round - 1, clock0, self.clock,
                                 staleness, len(self._pending),
                                 kind="flush")
        self._prune_flights()
        return [e.key for e in done], staleness

    # --------------------------------------------------- checkpoint state
    def export_state(self) -> dict:
        """Everything the timeline needs to resume bit-exactly on an
        identically-configured driver: clock/round/ledger scalars, the
        pending-event and download heaps, live flights (with their
        frozen PhaseCosts), the stateful links/queue, and the
        fault-ledger maps. Config (mode, quorum, devices, cost model,
        fault plan) is NOT serialized — the caller reconstructs it and
        calls ``restore_state``. JSON-safe: every float survives a
        json round-trip bit-exactly (repr-based), dict keys are encoded
        as pair-lists."""
        def _pc(pc: PhaseCost) -> dict:
            return dataclasses.asdict(pc)

        flights = []
        for uid in sorted(self._flights):
            fl = self._flights[uid]
            flights.append({
                "uid": fl.uid, "cid": fl.cid, "round": fl.round,
                "fid": fl.fid, "jid": fl.jid, "did": fl.did,
                "key": fl.key, "commit": fl.commit, "dl_end": fl.dl_end,
                "dispatch": fl.dispatch, "up_end": fl.up_end,
                "pc": _pc(fl.pc)})
        st = {
            "clock": self.clock, "comm": self.comm, "round": self.round,
            "seq": self._seq, "load": self._load,
            "next_uid": self._next_uid,
            "pending": [[e.ready, e.seq, e.round, e.key]
                        for e in sorted(self._pending,
                                        key=lambda e: (e.ready, e.seq))],
            "downloads": sorted(self._downloads),
            "flights": flights,
            "dev_busy": sorted(self._dev_busy.items(),
                               key=lambda kv: str(kv[0])),
            "uplink": (self._uplink.export_state()
                       if self._uplink is not None else None),
            "downlink": (self._downlink.export_state()
                         if self._downlink is not None else None),
            "srvq": (self._srvq.export_state()
                     if self._srvq is not None else None),
            "members": [[[r, k], sorted(v.items(),
                                        key=lambda kv: str(kv[0]))]
                        for (r, k), v in sorted(
                            self._members.items(),
                            key=lambda kv: (kv[0][0], str(kv[0][1])))],
            "dead": sorted(self._dead.items(),
                           key=lambda kv: str(kv[0])),
            "incarnation": sorted(self._incarnation.items(),
                                  key=lambda kv: str(kv[0])),
            "abandoned_ids": sorted([[r, k] for r, k
                                     in self._abandoned_ids],
                                    key=lambda rk: (rk[0], str(rk[1]))),
            "n_dispatched": self.n_dispatched,
            "n_committed": self.n_committed,
            "n_abandoned": self.n_abandoned,
        }
        if hasattr(self.scheduler, "export_state"):
            st["scheduler"] = self.scheduler.export_state()
        if self._history is not None:
            st["history"] = self._history.export_state()
            st["last_split"] = sorted(self._last_split.items(),
                                      key=lambda kv: str(kv[0]))
        if self.knob_controller is not None:
            st["knobs"] = self.knob_controller.export_state()
            st["knobs_applied"] = [self.quorum, self.staleness_cap]
        if self._fleet is not None:
            st["fleet"] = self._fleet.export_state()
        return st

    def restore_state(self, st: dict):
        """Inverse of ``export_state`` on a freshly-constructed,
        identically-configured driver. Keys that were tuples before a
        JSON round-trip come back as lists — re-tupled here so heap
        membership and ledger identity keep working."""
        def _key(k):
            return tuple(k) if isinstance(k, list) else k

        self.clock = float(st["clock"])
        self.comm = float(st["comm"])
        self.round = int(st["round"])
        self._seq = int(st["seq"])
        self._load = int(st["load"])
        self._next_uid = int(st["next_uid"])
        self._pending = [_Event(float(r), int(s), int(rd), _key(k))
                         for r, s, rd, k in st["pending"]]
        heapq.heapify(self._pending)
        self._downloads = [(float(r), int(u)) for r, u in st["downloads"]]
        heapq.heapify(self._downloads)
        self._flights = {}
        for f in st["flights"]:
            pc = PhaseCost(**{k: (None if v is None else float(v))
                              for k, v in f["pc"].items()})
            fl = _Flight(uid=int(f["uid"]), cid=f["cid"],
                         round=int(f["round"]), fid=int(f["fid"]),
                         jid=int(f["jid"]), pc=pc,
                         did=None if f["did"] is None else int(f["did"]),
                         key=_key(f["key"]),
                         commit=float(f["commit"]),
                         dl_end=float(f["dl_end"]),
                         dispatch=float(f["dispatch"]),
                         up_end=float(f["up_end"]))
            self._flights[fl.uid] = fl
        self._round_uids = {}
        self._dev_busy = {c: float(t) for c, t in st["dev_busy"]}
        self._uplink = (FluidLink.from_state(st["uplink"])
                        if st["uplink"] is not None else None)
        self._downlink = (FluidLink.from_state(st["downlink"])
                          if st["downlink"] is not None else None)
        self._srvq = (_ServerQueue.from_state(st["srvq"])
                      if st["srvq"] is not None else None)
        self._members = {(int(r), _key(k)): {c: float(t) for c, t in v}
                         for (r, k), v in st["members"]}
        self._dead = {c: int(r) for c, r in st["dead"]}
        self._incarnation = {c: int(n) for c, n in st["incarnation"]}
        self._abandoned_ids = {(int(r), _key(k))
                               for r, k in st["abandoned_ids"]}
        self.n_dispatched = int(st["n_dispatched"])
        self.n_committed = int(st["n_committed"])
        self.n_abandoned = int(st["n_abandoned"])
        if "scheduler" in st and hasattr(self.scheduler, "restore_state"):
            self.scheduler.restore_state(st["scheduler"])
        if "history" in st and self._history is not None:
            self._history.restore_state(st["history"])
            self._last_split = {c: int(s)
                                for c, s in st["last_split"]}
        if "knobs" in st and self.knob_controller is not None:
            self.knob_controller.restore_state(st["knobs"])
            q, cap = st["knobs_applied"]
            self.quorum = float(q)
            self.staleness_cap = int(cap)
        if "fleet" in st and self._fleet is not None:
            self._fleet.restore_state(st["fleet"])
