"""RoundDriver — THE warm-up → select → execute → observe → advance-clock
loop (single implementation; benchmarks, tests and the engine all drive
rounds through here instead of re-implementing it).

Three layers:

``CostModel``
    What a device-round costs: ``time_and_bytes(dev, split, clock)`` →
    Eq.-1 wall time + wire bytes, and ``phase_cost(...)`` → the
    upload / server-compute / download decomposition the pipelined
    timeline schedules. ``AnalyticCost`` prices payloads with the
    channel's analytic codec estimates (the benchmark/tests path);
    ``MeteredCost`` uses the exact bytes the ``CommChannel`` metered
    while real tensors crossed it (the ``S2FLEngine`` path); and
    ``FedAvgCost`` prices the full-model baseline. ``CallableCost``
    wraps a plain ``t_of(cid, split)`` for unit tests.

``RoundDriver.run_round``
    One round: during §3.1 warm-up, observe every device's Eq.-1 time so
    the scheduler's client time table fills; select splits; optionally
    call back into the caller (the engine trains for real here and
    returns metered payload bytes + its Eq.-2 groups); observe the
    participants' times; advance the clock.

Execution modes (the clock semantics):
    ``sync``       the paper's Eq.-1 barrier — the round's clock advance
                   is ``max`` over participant times; everything commits
                   in the round it was dispatched.
    ``semi_async`` device/group completions are events in a heap. The
                   aggregation window closes once a ``quorum`` fraction
                   of this round's arrivals are in; stragglers keep
                   running and commit in the window where their event
                   lands, at most ``staleness_cap`` rounds late (the
                   window blocks on any event that would otherwise
                   exceed the cap — ``staleness_cap=0`` degenerates to
                   ``sync``). The clock is a true event timeline: on a
                   static link semi_async wall-clock never exceeds sync
                   (each window closes at or before the sync barrier).

Phase pipeline (``pipeline=True``, orthogonal to the exec mode): each
device-round is split into three chained phase events instead of one
atomic Eq.-1 event —

    upload          Wc dispatch + client forward + features over the
                    uplink (concurrent uploads contend for the shared
                    ingress capacity when the channel bounds it);
    server compute  the group backward — the COMMIT event: windows
                    close, staleness is accounted, and aggregation
                    happens here;
    download        feature gradients + client backward + Wc
                    collection, draining in the background (tracked in
                    a second heap; ``flush()`` waits them out so the
                    final wall-clock is honest).

Because an update commits when its server compute finishes rather than
when its download lands, the server starts one group's backward while
another group's upload is still in flight — with contention and latency
off, every commit can only move earlier, so the pipelined wall-clock is
a lower bound on the phase-sequential one (property-tested in
tests/test_driver_properties.py).

Predictive split selection: with ``predictive=True`` the driver installs
a ``forecast`` hook on the scheduler — instead of trusting the EMA time
table alone, each candidate time is re-priced with the link model's
MEAN rate over the projected completion window ``[clock, clock + ema]``
(``CommChannel.mean_rate`` → ``LinkTrace`` exact integral), so a fade
that will hit mid-round is anticipated rather than discovered. When the
channel bounds the shared uplink, the forecast rate is additionally
capped at ``capacity / round_load`` — the contention-adjusted rate the
device will actually see.

See ``core/README.md`` for the design discussion.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Callable, Optional

from repro.comm.channel import MESSAGES_PER_ROUND
from repro.comm.links import shared_link_finish_times
from repro.core.simulation import (BYTES_PER_ELEM, CLIENT_FWD_FRAC,
                                   SERVER_FLOPS, device_round_time_bytes,
                                   fedavg_round_comm_bytes,
                                   fedavg_round_time,
                                   fedavg_round_time_bytes)

EXEC_MODES = ("sync", "semi_async")


def _cid(dev):
    """Device handle -> client id (accepts Device objects or bare ids)."""
    return getattr(dev, "cid", dev)


# ---------------------------------------------------------------------------
# cost models
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PhaseCost:
    """One device-round decomposed for the pipelined timeline.

    Transfer rates are frozen at the dispatch clock (piecewise-constant
    traces make this exact within a segment); the feature upload is the
    only segment that contends for the shared ingress, so it is kept as
    (bytes, own-rate) for the fluid scheduler while everything else is
    already seconds."""
    t_pre: float           # Wc dispatch transfer + client fwd (+ 2 lat)
    up_bytes: float        # feature payload on the shared uplink
    up_rate: float         # device's own uplink bytes/s at dispatch
    t_srv: float           # server compute (the commit phase)
    t_down: float          # dfx down + client bwd + Wc collect (+ 2 lat)
    total_bytes: float     # full wire traffic (= the atomic accounting)


class CostModel:
    """(time, bytes) of one device-round at simulated time ``clock``.

    ``payload_bytes`` / ``dispatch_bytes`` carry exact channel-metered
    cut-layer and model-leg bytes when the caller materialized tensors
    (None -> analytic estimates)."""

    def time_and_bytes(self, dev, split: int, clock: float,
                       payload_bytes: Optional[float] = None,
                       dispatch_bytes: Optional[float] = None):
        raise NotImplementedError

    def phase_cost(self, dev, split: int, clock: float,
                   up_payload: Optional[float] = None,
                   down_payload: Optional[float] = None,
                   disp_down: Optional[float] = None,
                   disp_up: Optional[float] = None
                   ) -> Optional[PhaseCost]:
        """Upload/server/download decomposition for the pipelined
        timeline (None -> no decomposition; the driver falls back to one
        atomic event for this device — e.g. the FedAvg baseline, which
        has no cut layer to pipeline around)."""
        return None

    def shared_uplink_bytes(self) -> float:
        """Shared ingress capacity in bytes/s (inf = uncontended)."""
        return math.inf

    def forecast_time(self, dev, split: int, clock: float,
                      horizon: float, load: int = 1) -> Optional[float]:
        """Predicted round time if dispatched now and finishing ~horizon
        later (None -> no prediction, caller falls back to the EMA).
        ``load`` is the number of devices expected to share the uplink
        this round (contention-adjusts the forecast rate)."""
        return None


class AnalyticCost(CostModel):
    """Eq.-1 via the channel's analytic payload estimates — what every
    benchmark and scheduler test uses (no tensors ever materialize).

    costs: {split: {'wc_size','feat_size','fc','fs'}} per-sample Eq.-1
    quantities (``repro.utils.flops.split_costs``) or a callable
    ``split -> dict`` (resolved lazily and cached). ``p`` is the local
    sample count per round; ``p_of(cid)`` overrides it per client.
    """

    def __init__(self, channel, costs, *, p: int = 128,
                 p_of: Optional[Callable] = None):
        self.channel = channel
        self._costs = costs if callable(costs) else costs.__getitem__
        self._cache: dict = {}
        self.p_of = p_of or (lambda cid: p)

    def cost(self, split: int) -> dict:
        if split not in self._cache:
            self._cache[split] = self._costs(split)
        return self._cache[split]

    def time_and_bytes(self, dev, split, clock, payload_bytes=None,
                       dispatch_bytes=None):
        c, p = self.cost(split), self.p_of(_cid(dev))
        return self.channel.analytic_round_time(
            dev, wc_size=c["wc_size"], n_values=p * c["feat_size"],
            fc=p * c["fc"], fs=p * c["fs"], t=clock)

    def phase_cost(self, dev, split, clock, up_payload=None,
                   down_payload=None, disp_down=None, disp_up=None):
        c, p = self.cost(split), self.p_of(_cid(dev))
        ch = self.channel
        rate = ch.rate(dev, clock) * BYTES_PER_ELEM
        n_values = p * c["feat_size"]
        up = (up_payload if up_payload is not None
              else ch.estimate_uplink_payload(n_values))
        down = (down_payload if down_payload is not None
                else ch.estimate_downlink_payload(n_values))
        # one-way model transfers (dispatch codec; fp32 reproduces the
        # seed's wc_size * BYTES_PER_ELEM)
        wc_down = (disp_down if disp_down is not None
                   else ch.estimate_dispatch_leg(c["wc_size"]))
        wc_up = (disp_up if disp_up is not None
                 else ch.estimate_dispatch_leg(c["wc_size"]))
        fc, fs = p * c["fc"], p * c["fs"]
        # half the round's messages ride each client-side phase, so the
        # atomic and phase paths charge the same total latency
        lat2 = 0.5 * MESSAGES_PER_ROUND * ch.latency
        return PhaseCost(
            t_pre=lat2 + wc_down / rate
            + CLIENT_FWD_FRAC * fc / dev.comp,
            up_bytes=up, up_rate=rate,
            t_srv=fs / SERVER_FLOPS,
            t_down=lat2 + (down + wc_up) / rate
            + (1.0 - CLIENT_FWD_FRAC) * fc / dev.comp,
            total_bytes=wc_down + wc_up + up + down)

    def shared_uplink_bytes(self):
        cap = getattr(self.channel, "uplink_capacity", 0.0)
        return cap * BYTES_PER_ELEM if cap else math.inf

    def forecast_time(self, dev, split, clock, horizon, load=1):
        c, p = self.cost(split), self.p_of(_cid(dev))
        nbytes = self.channel.estimate_dispatch_round(c["wc_size"]) \
            + self.channel.estimate_round_payload(p * c["feat_size"])
        rate = self.channel.mean_rate(dev, clock,
                                      clock + max(horizon, 1e-9))
        cap = getattr(self.channel, "uplink_capacity", 0.0)
        if cap:
            # contention-adjusted: the shared ingress split across the
            # round's cohort bounds what this device will actually see
            # (even a solo upload is capped at the full ingress, exactly
            # as the fluid schedule caps it)
            rate = min(rate, cap / max(load, 1))
        return device_round_time_bytes(dev, comm_bytes=nbytes,
                                       fc=p * c["fc"], fs=p * c["fs"],
                                       rate=rate) \
            + MESSAGES_PER_ROUND * self.channel.latency


class MeteredCost(AnalyticCost):
    """Engine path: when the channel metered real payload bytes for a
    participant, price exactly those; otherwise (warm-up observation of
    devices whose tensors never materialize, forecasts) fall back to the
    analytic estimate."""

    def time_and_bytes(self, dev, split, clock, payload_bytes=None,
                       dispatch_bytes=None):
        if payload_bytes is None:
            return super().time_and_bytes(dev, split, clock)
        c, p = self.cost(split), self.p_of(_cid(dev))
        disp = (dispatch_bytes if dispatch_bytes is not None
                else self.channel.estimate_dispatch_round(c["wc_size"]))
        nbytes = disp + payload_bytes
        t = device_round_time_bytes(
            dev, comm_bytes=nbytes, fc=p * c["fc"], fs=p * c["fs"],
            rate=self.channel.rate(dev, clock)) \
            + MESSAGES_PER_ROUND * self.channel.latency
        return t, nbytes


class FedAvgCost(CostModel):
    """Full-model FedAvg baseline round cost (split is ignored). No cut
    layer, so there is nothing to phase-split: under ``pipeline=True``
    FedAvg rounds stay atomic events.

    With a ``channel`` the model legs are priced through its dispatch
    codec (the QSGD-style compressed-FedAvg baseline: broadcast down,
    compressed update up); exact metered ``dispatch_bytes`` override
    the analytic estimate when the engine materialized the transfer."""

    def __init__(self, costs_full, *, p: int = 128,
                 p_of: Optional[Callable] = None, channel=None):
        self._costs = costs_full if callable(costs_full) \
            else (lambda: costs_full)
        self._cache = None
        self.p_of = p_of or (lambda cid: p)
        self.channel = channel

    def cost(self) -> dict:
        if self._cache is None:
            self._cache = self._costs()
        return self._cache

    def time_and_bytes(self, dev, split, clock, payload_bytes=None,
                       dispatch_bytes=None):
        c, p = self.cost(), self.p_of(_cid(dev))
        if dispatch_bytes is not None:
            nbytes = dispatch_bytes
        elif self.channel is not None:
            nbytes = self.channel.estimate_dispatch_round(c["w_size"])
        else:
            nbytes = fedavg_round_comm_bytes(w_size=c["w_size"])
        if dispatch_bytes is None and self.channel is None:
            t = fedavg_round_time(dev, w_size=c["w_size"], p=p,
                                  f_full=c["f_full"])
        else:
            rate = (self.channel.rate(dev, clock) if self.channel
                    else None)
            t = fedavg_round_time_bytes(dev, comm_bytes=nbytes, p=p,
                                        f_full=c["f_full"], rate=rate)
        return t, nbytes


class CallableCost(CostModel):
    """Unit-test adapter: a plain ``t_of(cid, split)`` (clock-free) or
    ``t_of(cid, split, clock)`` time function, optional byte function,
    optional ``phases_of(cid, split) -> PhaseCost`` for pipelined
    tests."""

    def __init__(self, t_of: Callable, bytes_of: Optional[Callable] = None,
                 *, clocked: bool = False,
                 phases_of: Optional[Callable] = None):
        self.t_of, self.bytes_of, self.clocked = t_of, bytes_of, clocked
        self.phases_of = phases_of

    def time_and_bytes(self, dev, split, clock, payload_bytes=None,
                       dispatch_bytes=None):
        cid = _cid(dev)
        t = self.t_of(cid, split, clock) if self.clocked \
            else self.t_of(cid, split)
        return t, (self.bytes_of(cid, split) if self.bytes_of else 0.0)

    def phase_cost(self, dev, split, clock, up_payload=None,
                   down_payload=None, disp_down=None, disp_up=None):
        if self.phases_of is None:
            return None
        return self.phases_of(_cid(dev), split)


# ---------------------------------------------------------------------------
# the driver
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class RoundResult:
    round: int                     # round index just driven
    clock: float                   # driver clock after the window closed
    round_time: float              # clock advance this round
    comm_bytes: float              # wire bytes dispatched this round
    splits: dict                   # {cid: split} selected this round
    times: dict                    # {cid: Eq.-1 device time}
    committed: tuple               # work keys whose updates commit now
    staleness: dict                # {key: rounds late} for committed keys
    pending: int                   # commit events still in flight after
    phases: dict = dataclasses.field(default_factory=dict)
    #                              # {cid: {'up','srv','down'} durations}
    #                              # (pipelined rounds only)
    downloads: int = 0             # download events still draining


@dataclasses.dataclass(order=True)
class _Event:
    ready: float
    seq: int
    round: int = dataclasses.field(compare=False)
    key: object = dataclasses.field(compare=False)


class RoundDriver:
    """Owns the round loop and the simulated timeline.

    scheduler : Sliding/MinTime/FixedSplitScheduler (select/observe/
                end_round + the §3.1 warm-up protocol)
    cost      : a CostModel
    devices   : Device objects (or bare cids with a CallableCost)
    warmup_devices : subset observed during warm-up rounds (default: all
                devices — the engine restricts to devices that own data)
    pipeline  : phase-level event timeline (upload / server-compute /
                download) instead of one atomic event per device-round
    """

    def __init__(self, scheduler, cost: CostModel, devices, *,
                 mode: str = "sync", staleness_cap: int = 1,
                 quorum: float = 0.5, predictive: bool = False,
                 pipeline: bool = False, warmup_devices=None):
        if mode not in EXEC_MODES:
            raise ValueError(f"exec mode {mode!r}; known: {EXEC_MODES}")
        if staleness_cap < 0:
            raise ValueError(f"staleness_cap must be >= 0: {staleness_cap}")
        if not 0.0 < quorum <= 1.0:
            raise ValueError(f"quorum must be in (0, 1]: {quorum}")
        self.scheduler = scheduler
        self.cost = cost
        self.devices = list(devices)
        self.warmup_devices = (list(warmup_devices)
                               if warmup_devices is not None
                               else self.devices)
        self._dev_by_id = {_cid(d): d for d in self.devices}
        self.mode = mode
        self.staleness_cap = staleness_cap
        self.quorum = quorum
        self.pipeline = bool(pipeline)
        self.clock = 0.0
        self.comm = 0.0                 # accumulated wire bytes
        self.round = 0
        self._pending: list = []        # _Event heap (commit events)
        self._downloads: list = []      # (ready, seq, cid) heap (pipeline)
        self._seq = 0
        self._load = 1                  # current round's cohort size
        if predictive:
            if not hasattr(scheduler, "forecast"):
                raise ValueError(
                    f"{type(scheduler).__name__} has no forecast hook; "
                    "predictive mode needs a sliding scheduler")
            scheduler.forecast = self._forecast

    # -------------------------------------------------------- predictive
    def _forecast(self, cid, split, recorded):
        """Scheduler hook: re-price the EMA entry with the link's mean
        rate over the projected completion window [clock, clock+ema],
        contention-adjusted by the round's cohort size."""
        dev = self._dev_by_id.get(cid)
        if dev is None:
            return None
        return self.cost.forecast_time(dev, split, self.clock, recorded,
                                       load=self._load)

    # ------------------------------------------------------------- round
    def run_round(self, participants, execute=None) -> RoundResult:
        """Drive one round. ``participants``: cids or Device objects.

        ``execute(splits) -> report`` (optional) runs the caller's real
        work after selection; the report dict may carry
        ``payload_bytes`` ({cid: metered wire bytes, cut-layer only}),
        ``payload_up_bytes`` / ``payload_down_bytes`` (the per-direction
        split the pipelined timeline prices), ``dispatch_bytes``
        ({cid: metered model-leg bytes, dispatch + collect} with the
        per-direction ``dispatch_down_bytes`` / ``dispatch_up_bytes``)
        and ``groups`` ({work_key: (cid, ...)} — commit granularity;
        default one work item per participant keyed by cid).
        """
        part = [_cid(p) for p in participants]
        part_set = set(part)
        clock0 = self.clock
        self._load = max(1, len(part))

        # §3.1 warm-up: the shared split is dispatched to ALL devices so
        # the whole client time table fills; participants are observed
        # below with their (possibly metered) round times instead.
        if getattr(self.scheduler, "warming_up", False):
            s = self.scheduler.warmup_split()
            for d in self.warmup_devices:
                if _cid(d) in part_set:
                    continue
                t, _ = self.cost.time_and_bytes(d, s, clock0)
                self.scheduler.observe(_cid(d), s, t)

        splits = self.scheduler.select(part)
        plan = getattr(self.scheduler, "plan", None)
        if plan is not None:
            assert all(splits[c] in plan for c in part), splits

        report = execute(splits) if execute is not None else None
        payloads = (report or {}).get("payload_bytes", {})
        pay_up = (report or {}).get("payload_up_bytes", {})
        pay_down = (report or {}).get("payload_down_bytes", {})
        dispatch = (report or {}).get("dispatch_bytes", {})
        disp_down = (report or {}).get("dispatch_down_bytes", {})
        disp_up = (report or {}).get("dispatch_up_bytes", {})
        groups = (report or {}).get("groups")
        if groups is None:
            groups = {c: (c,) for c in part}

        phases: dict = {}
        if self.pipeline:
            commits, times, comm, phases = self._phase_schedule(
                part, splits, payloads, pay_up, pay_down,
                disp_down, disp_up, clock0)
        else:
            times, comm = {}, 0.0
            for c in part:
                dev = self._dev_by_id.get(c, c)
                t, nbytes = self.cost.time_and_bytes(
                    dev, splits[c], clock0,
                    payload_bytes=payloads.get(c),
                    dispatch_bytes=dispatch.get(c))
                times[c] = t
                comm += nbytes
            commits = {c: clock0 + times[c] for c in part}
        for c in part:
            self.scheduler.observe(c, splits[c], times[c])

        items = {key: max(commits[c] for c in members)
                 for key, members in groups.items() if members}
        committed, staleness, new_clock = self._close_window(items, clock0)
        self._drain_downloads(new_clock)

        self.clock = new_clock
        self.comm += comm
        self.scheduler.end_round()
        rec = RoundResult(
            round=self.round, clock=self.clock,
            round_time=new_clock - clock0, comm_bytes=comm, splits=splits,
            times=times, committed=tuple(committed), staleness=staleness,
            pending=len(self._pending), phases=phases,
            downloads=len(self._downloads))
        self.round += 1
        return rec

    # --------------------------------------------------- phase pipeline
    def _phase_schedule(self, part, splits, payloads, pay_up, pay_down,
                        disp_down, disp_up, clock0):
        """Chain upload → server-compute → download events per device.
        Returns ({cid: commit time}, {cid: full round duration},
        round wire bytes, {cid: phase durations}).

        Commit = end of the device's server-compute share (its own
        Eq.-1 Fs term chained on its own upload — the server starts
        folding a member's contribution in as soon as it arrives, which
        is exactly the upload/backward overlap the pipeline buys).
        Downloads drain in the background: they gate ``flush()`` and the
        honest final wall-clock, not the aggregation windows."""
        quants = {}
        for c in part:
            dev = self._dev_by_id.get(c, c)
            quants[c] = self.cost.phase_cost(
                dev, splits[c], clock0, up_payload=pay_up.get(c),
                down_payload=pay_down.get(c),
                disp_down=disp_down.get(c), disp_up=disp_up.get(c))

        jobs, order = [], []
        for c, pc in quants.items():
            if pc is not None:
                jobs.append((clock0 + pc.t_pre, pc.up_bytes, pc.up_rate))
                order.append(c)
        fins = shared_link_finish_times(jobs,
                                        self.cost.shared_uplink_bytes())
        up_end = dict(zip(order, fins))

        commits, times, phases, comm = {}, {}, {}, 0.0
        for c, pc in quants.items():
            if pc is None:             # no decomposition: atomic event
                dev = self._dev_by_id.get(c, c)
                disp = (disp_down.get(c, 0.0) + disp_up.get(c, 0.0)
                        if c in disp_down or c in disp_up else None)
                t, nbytes = self.cost.time_and_bytes(
                    dev, splits[c], clock0,
                    payload_bytes=payloads.get(c), dispatch_bytes=disp)
                commits[c] = clock0 + t
                times[c] = t
                comm += nbytes
                continue
            commit = up_end[c] + pc.t_srv
            dl_end = commit + pc.t_down
            commits[c] = commit
            times[c] = dl_end - clock0
            comm += pc.total_bytes
            phases[c] = {"up": up_end[c] - clock0, "srv": pc.t_srv,
                         "down": pc.t_down}
            heapq.heappush(self._downloads, (dl_end, self._seq, c))
            self._seq += 1
        return commits, times, comm, phases

    def _drain_downloads(self, horizon):
        while self._downloads and self._downloads[0][0] <= horizon:
            heapq.heappop(self._downloads)

    # ------------------------------------------------------ event window
    def _push(self, key, ready):
        heapq.heappush(self._pending,
                       _Event(ready, self._seq, self.round, key))
        self._seq += 1

    def _pop_ready(self, horizon):
        out = []
        while self._pending and self._pending[0].ready <= horizon:
            out.append(heapq.heappop(self._pending))
        return out

    def _close_window(self, items: dict, now: float):
        """items: {key: absolute commit-ready time}. Returns (committed
        keys, staleness per key in rounds, new clock)."""
        for key, ready in items.items():
            self._push(key, ready)
        if self.mode == "sync" or self.staleness_cap == 0:
            # barrier: everything dispatched must land this round
            new_clock = max((e.ready for e in self._pending), default=now)
        elif not self._pending:
            return [], {}, now
        else:
            fresh = sorted(items.values())
            q = max(1, math.ceil(self.quorum * len(fresh))) if fresh else 0
            t_quorum = fresh[q - 1] if fresh else now
            # any event that would exceed the staleness cap by waiting
            # for the NEXT window must be waited for in this one
            forced = [e.ready for e in self._pending
                      if e.round <= self.round - self.staleness_cap]
            new_clock = max([t_quorum, now] + forced)
        done = self._pop_ready(new_clock)
        committed = [e.key for e in done]
        staleness = {e.key: self.round - e.round for e in done}
        assert all(v <= max(self.staleness_cap, 0)
                   for v in staleness.values()), staleness
        return committed, staleness, new_clock

    def flush(self):
        """Wait out every in-flight event (end of training): advances the
        clock past the last pending commit AND the last draining
        download, commits everything. Returns (committed keys, staleness
        dict)."""
        ready = [e.ready for e in self._pending] \
            + [r for r, _, _ in self._downloads]
        if not ready:
            return [], {}
        new_clock = max(ready)
        done = self._pop_ready(new_clock)
        self._drain_downloads(new_clock)
        self.clock = max(self.clock, new_clock)
        return [e.key for e in done], \
            {e.key: self.round - 1 - e.round for e in done}
