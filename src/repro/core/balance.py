"""Data balance-based training mechanism (§3.2, Eq. 2).

The Main Server sees per-client label histograms (labels ride along with
features in SFL-V2 semantics) and groups the x participating clients so
each group's combined label distribution is as close to uniform as
possible, measured by

    Dist(G) = || sum_{c in G} D_c / |sum| - 1/n ||_2            (Eq. 2)

The paper specifies the objective, not the algorithm; we use greedy
seeding (most-skewed client first, then repeatedly add the client that
most reduces the distance) followed by a single-pass swap refinement.
An exhaustive search oracle is provided for small x (used in tests to
bound the greedy gap).
"""
from __future__ import annotations

import itertools

import numpy as np


def eq2_distance(hist_sum: np.ndarray) -> float:
    """Eq. 2 on an (n_classes,) combined count vector."""
    total = hist_sum.sum()
    if total == 0:
        return float(np.sqrt(len(hist_sum))) / len(hist_sum)
    p = hist_sum / total
    return float(np.linalg.norm(p - 1.0 / len(hist_sum)))


def group_distance(hists, group) -> float:
    return eq2_distance(np.sum([hists[c] for c in group], axis=0))


def greedy_groups(hists, group_size: int):
    """hists: (x, n_classes) counts. Returns list of groups (tuples of
    client indices), each of ~group_size members."""
    hists = np.asarray(hists, dtype=np.float64)
    x = len(hists)
    n_groups = max(1, round(x / group_size))
    # assign sizes as evenly as possible
    sizes = [x // n_groups + (1 if i < x % n_groups else 0)
             for i in range(n_groups)]
    unassigned = set(range(x))
    skew = {c: eq2_distance(hists[c]) for c in unassigned}
    groups = []
    for gs in sizes:
        seed = max(unassigned, key=lambda c: skew[c])
        group = [seed]
        unassigned.discard(seed)
        acc = hists[seed].copy()
        for _ in range(gs - 1):
            if not unassigned:
                break
            best = min(unassigned, key=lambda c: eq2_distance(acc + hists[c]))
            group.append(best)
            unassigned.discard(best)
            acc += hists[best]
        groups.append(tuple(group))
    groups = _swap_refine(hists, groups)
    return groups


def _swap_refine(hists, groups, passes: int = 1):
    groups = [list(g) for g in groups]
    for _ in range(passes):
        improved = False
        for gi in range(len(groups)):
            for gj in range(gi + 1, len(groups)):
                for ii in range(len(groups[gi])):
                    for jj in range(len(groups[gj])):
                        base = (group_distance(hists, groups[gi])
                                + group_distance(hists, groups[gj]))
                        groups[gi][ii], groups[gj][jj] = \
                            groups[gj][jj], groups[gi][ii]
                        new = (group_distance(hists, groups[gi])
                               + group_distance(hists, groups[gj]))
                        if new < base - 1e-12:
                            improved = True
                        else:
                            groups[gi][ii], groups[gj][jj] = \
                                groups[gj][jj], groups[gi][ii]
        if not improved:
            break
    return [tuple(g) for g in groups]


def exhaustive_groups(hists, group_size: int):
    """Brute-force oracle (small x only): minimizes summed Eq. 2 distance
    over all partitions into groups of the given size."""
    hists = np.asarray(hists, dtype=np.float64)
    x = len(hists)
    assert x % group_size == 0 and x <= 8, "oracle is for small tests"

    best, best_d = None, np.inf

    def partitions(items):
        if not items:
            yield []
            return
        first = items[0]
        for combo in itertools.combinations(items[1:], group_size - 1):
            group = (first,) + combo
            rest = [i for i in items if i not in group]
            for sub in partitions(rest):
                yield [group] + sub

    for part in partitions(list(range(x))):
        d = sum(group_distance(hists, g) for g in part)
        if d < best_d:
            best, best_d = part, d
    return best


def label_histogram(labels, n_classes: int) -> np.ndarray:
    return np.bincount(np.asarray(labels).reshape(-1), minlength=n_classes
                       ).astype(np.float64)[:n_classes]


def balance_permutation(client_ids, groups, per_client: int):
    """Global-batch permutation realizing the grouping for the fused SPMD
    round step: clients' feature slabs (per_client rows each, ordered by
    client_ids) are permuted so each group's rows become contiguous.

    Returns perm with perm[new_row] = old_row (use as x[perm])."""
    index_of = {c: i for i, c in enumerate(client_ids)}
    perm = []
    for g in groups:
        for c in g:
            base = index_of[c] * per_client
            perm.extend(range(base, base + per_client))
    return np.asarray(perm, dtype=np.int32)
