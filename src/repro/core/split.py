"""SplitPlan — the paper's three-portion model division (§3, §3.1).

The full model's sequential units are divided into:
  client-side portion : units [0, min(split_points))   — always on device
  shared portion      : units [min, max(split_points)) — slides per device
  server-side portion : units [max(split_points), n)   — always on server

A split index ``s`` (one of the K candidate split points) assigns
``stem + units[:s]`` to the client. The paper uses K=3 split layers per
model; K is configurable here.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class SplitPlan:
    n_units: int
    split_points: tuple          # ascending candidate split indices

    def __post_init__(self):
        assert self.split_points == tuple(sorted(set(self.split_points)))
        assert all(0 < s <= self.n_units for s in self.split_points)

    @property
    def k(self) -> int:
        return len(self.split_points)

    @property
    def client_side_end(self) -> int:      # end of always-client portion
        return min(self.split_points)

    @property
    def shared_end(self) -> int:           # end of shared portion
        return max(self.split_points)

    def __contains__(self, split: int) -> bool:
        """True when ``split`` is one of the K candidate split points —
        the RoundDriver validates every scheduler selection with this."""
        return split in self.split_points

    def smallest(self) -> int:
        return self.split_points[0]

    def largest(self) -> int:
        return self.split_points[-1]


def default_plan(n_units: int, k: int = 3,
                 fractions=(0.125, 0.25, 0.5)) -> SplitPlan:
    """K split points in the shallow half of the stack (client devices are
    resource-constrained — the paper's Figure 3 splits are all shallow)."""
    fr = fractions[:k] if len(fractions) >= k else tuple(
        (i + 1) / (k + 1) * 0.5 for i in range(k))
    pts = sorted({max(1, round(n_units * f)) for f in fr})
    # guarantee k distinct points on shallow stacks
    nxt = 1
    while len(pts) < k and nxt <= n_units:
        if nxt not in pts:
            pts.append(nxt)
        nxt += 1
    return SplitPlan(n_units=n_units, split_points=tuple(sorted(pts)[:k]))
