"""Batched population state for million-device fleets.

`RoundDriver` iterates Python `_Flight`/`Device` objects per cohort
member — fine for cohorts of tens, hopeless if the *population* had to
be materialized that way at 10^6 devices.  `Fleet` keeps the population
as flat ``(P,)`` numpy tables (device FLOP/s, link elements/s, diurnal
phase, EF-residual mass) plus a *sparse* dead-set, and materializes
`Device` objects lazily — only for the O(active cohort) devices a round
actually samples.  Construction is O(P) once; every per-round operation
(cohort sampling, churn, availability) is O(active cohort + churned),
never O(P).

Exactness contract: ``Fleet.table1(P, seed, composition)`` consumes the
*identical* `numpy.random.Generator` stream as
`simulation.make_device_grid(P, seed, composition)` (same `choice`
calls, and `Generator.shuffle` applies the same permutation to an index
vector as to the materialized list), so ``fleet.device(i)`` equals the
object grid's ``devices[i]`` bit-for-bit.  That is what lets the fleet
driver reproduce the object driver's clock exactly at small N
(`tests/test_fleet.py`).

Every stochastic draw (cohort sampling, churn) derives its Generator
from ``(seed, round)`` so replay after `restore_state` is exact and
independent of call order or history — a mid-run checkpoint restore
resumes the same trace.
"""
from __future__ import annotations

import numpy as np

from repro.core.simulation import (
    FLOPS_SETTINGS,
    RATE_SETTINGS,
    SERVER_FLOPS,
    Device,
)

# Domain-separation tags for the per-purpose seed streams.
_TAG_PHASE = 0xD1A2
_TAG_CHURN = 0xC0DE
_TAG_SAMPLE = 0x5EED


class Fleet:
    """(P,) population tables with seeded cohort sampling and churn.

    Parameters
    ----------
    comp, rate : (P,) arrays — device FLOP/s and link elements/s.
    seed : base seed; all internal streams derive from it.
    clusters : number of edge clusters for hierarchical aggregation
        (``cid % clusters``); ``0``/``1`` means flat (no hierarchy).
    diurnal_period : availability period in rounds (0 = always-on).
        Each device gets a random phase; it is available in a
        ``diurnal_duty`` fraction of each period.
    churn_kill_prob / churn_rejoin_prob : per-round per-device death
        probability and per-round per-dead-device revival probability.
        Dead devices are never sampled into a cohort.
    """

    def __init__(self, comp, rate, *, seed: int = 0, clusters: int = 0,
                 diurnal_period: int = 0, diurnal_duty: float = 1.0,
                 churn_kill_prob: float = 0.0,
                 churn_rejoin_prob: float = 0.5):
        comp = np.ascontiguousarray(comp, dtype=np.float64)
        rate = np.ascontiguousarray(rate, dtype=np.float64)
        if comp.ndim != 1 or comp.shape != rate.shape:
            raise ValueError("comp/rate must be equal-length 1-D tables")
        if not 0.0 < diurnal_duty <= 1.0:
            raise ValueError(f"diurnal_duty must be in (0, 1]: {diurnal_duty}")
        self.comp = comp
        self.rate = rate
        self.seed = int(seed)
        self.clusters = int(clusters)
        self.diurnal_period = int(diurnal_period)
        self.diurnal_duty = float(diurnal_duty)
        self.churn_kill_prob = float(churn_kill_prob)
        self.churn_rejoin_prob = float(churn_rejoin_prob)
        rng = np.random.default_rng((self.seed, _TAG_PHASE))
        self.phase = rng.random(self.population)
        # EF residual mass per device (elements pending re-send); the
        # driver folds the channel's per-device figure back in after
        # each round so the table tracks only sampled devices — sparse
        # in practice, dense in storage (8 B/device).
        self.residual_mass = np.zeros(self.population, dtype=np.float64)
        self._dead: dict = {}        # cid -> round killed (sparse)
        self._churn_round = -1       # churn applied through this round

    # ------------------------------------------------------------------
    # construction
    @classmethod
    def table1(cls, population: int, seed: int = 0, composition=None,
               **kwargs) -> "Fleet":
        """Vectorized dual of `simulation.make_device_grid` — same rng
        stream, same kind assignment, identical per-cid devices."""
        n = int(population)
        rng = np.random.default_rng(seed)
        flops_vals = np.array(list(FLOPS_SETTINGS.values()))
        rate_vals = np.array(list(RATE_SETTINGS.values()))
        if composition is None:
            # kinds[k] = (flops_keys[k // 3], rate_keys[k % 3])
            ki = np.arange(n) % (len(flops_vals) * len(rate_vals))
            fi, ri = ki // len(rate_vals), ki % len(rate_vals)
        else:
            quals = list(composition)
            weights = np.array([composition[q] for q in quals], float)
            weights /= weights.sum()
            fq = rng.choice(quals, size=n, p=weights)
            rq = rng.choice(quals, size=n, p=weights)
            flops_keys = list(FLOPS_SETTINGS)
            rate_keys = list(RATE_SETTINGS)
            fi = np.array([flops_keys.index(q) for q in fq])
            ri = np.array([rate_keys.index(q) for q in rq])
        # Generator.shuffle applies the identical permutation to an
        # index vector as it would to the materialized picks list.
        perm = np.arange(n)
        rng.shuffle(perm)
        return cls(flops_vals[fi[perm]], rate_vals[ri[perm]],
                   seed=seed, **kwargs)

    @classmethod
    def from_devices(cls, devices, **kwargs) -> "Fleet":
        """Wrap an existing object grid (cids must be 0..P-1)."""
        devs = sorted(devices, key=lambda d: d.cid)
        if [d.cid for d in devs] != list(range(len(devs))):
            raise ValueError("from_devices needs contiguous 0..P-1 cids")
        return cls([d.comp for d in devs], [d.rate for d in devs], **kwargs)

    # ------------------------------------------------------------------
    # basic views
    @property
    def population(self) -> int:
        return int(self.comp.shape[0])

    @property
    def nbytes(self) -> int:
        """Table storage — the bounded-memory figure benchmarks assert."""
        return int(self.comp.nbytes + self.rate.nbytes
                   + self.phase.nbytes + self.residual_mass.nbytes)

    def device(self, cid) -> Device:
        """Materialize one Device — the only place population state
        becomes a Python object, and only for sampled cids."""
        i = int(cid)
        return Device(cid=i, comp=float(self.comp[i]),
                      rate=float(self.rate[i]))

    def devices_for(self, cids) -> list:
        return [self.device(c) for c in cids]

    def cluster_of(self, cid) -> int:
        return int(cid) % self.clusters if self.clusters > 1 else 0

    def as_jax(self) -> dict:
        """Population tables as jax arrays for accelerator consumers."""
        import jax.numpy as jnp
        return {"comp": jnp.asarray(self.comp),
                "rate": jnp.asarray(self.rate),
                "phase": jnp.asarray(self.phase),
                "residual_mass": jnp.asarray(self.residual_mass)}

    def eq1_times(self, cids=None, *, wc_size: float, feat_size: float,
                  p: float, fc: float, fs: float) -> np.ndarray:
        """Vectorized Eq. 1 `(2|Wc| + 2 p q)/R + Fc/Comp_c + Fs/Comp_s`
        over `cids` (None = whole population) in one batched call."""
        if cids is None:
            comp, rate = self.comp, self.rate
        else:
            idx = np.asarray(cids, dtype=np.int64)
            comp, rate = self.comp[idx], self.rate[idx]
        return ((2.0 * wc_size + 2.0 * p * feat_size) / rate
                + fc / comp + fs / SERVER_FLOPS)

    # ------------------------------------------------------------------
    # availability / churn
    def dead_set(self) -> set:
        return set(self._dead)

    def kill(self, cid, round_idx: int = 0) -> None:
        self._dead[int(cid)] = int(round_idx)

    def rejoin(self, cid) -> None:
        self._dead.pop(int(cid), None)

    def _is_available(self, cid: int, round_idx: int) -> bool:
        if cid in self._dead:
            return False
        if self.diurnal_period > 0:
            pos = (round_idx / self.diurnal_period + self.phase[cid]) % 1.0
            return bool(pos < self.diurnal_duty)
        return True

    def availability_mask(self, round_idx: int) -> np.ndarray:
        """O(P) dense mask — for tests and reports, not the round loop."""
        mask = np.ones(self.population, dtype=bool)
        if self._dead:
            mask[np.fromiter(self._dead, dtype=np.int64)] = False
        if self.diurnal_period > 0:
            pos = (round_idx / self.diurnal_period + self.phase) % 1.0
            mask &= pos < self.diurnal_duty
        return mask

    def _advance_churn(self, round_idx: int) -> None:
        for r in range(self._churn_round + 1, round_idx + 1):
            self._apply_churn(r)
        self._churn_round = max(self._churn_round, round_idx)

    def _apply_churn(self, r: int):
        """One round of deaths/revivals — O(dead + killed), seeded by
        (seed, round) so restores replay the identical trace."""
        if self.churn_kill_prob <= 0.0 and not self._dead:
            return [], []
        rng = np.random.default_rng((self.seed, r, _TAG_CHURN))
        rejoined = []
        for cid in sorted(self._dead):
            if rng.random() < self.churn_rejoin_prob:
                del self._dead[cid]
                rejoined.append(cid)
        killed = []
        if self.churn_kill_prob > 0.0:
            n_alive = self.population - len(self._dead)
            n_kill = int(rng.binomial(n_alive, self.churn_kill_prob))
            guard = 0
            while len(killed) < n_kill and guard < 64 * (n_kill + 4):
                c = int(rng.integers(self.population))
                guard += 1
                if c not in self._dead:
                    self._dead[c] = r
                    killed.append(c)
        return rejoined, killed

    # ------------------------------------------------------------------
    # cohort sampling
    def sample_cohort(self, round_idx: int, k: int) -> list:
        """Draw k distinct available cids for `round_idx` — O(k)
        expected via rejection sampling against the sparse dead-set,
        with a dense O(P) fallback only if availability is so low the
        rejection budget runs out.  Deterministic in (seed, round)."""
        self._advance_churn(round_idx)
        P = self.population
        k = max(0, min(int(k), P))
        rng = np.random.default_rng((self.seed, round_idx, _TAG_SAMPLE))
        chosen, seen = [], set()
        budget = 64 * max(k, 1) + 256
        while len(chosen) < k and budget > 0:
            batch = rng.integers(P, size=min(budget, max(2 * k, 16)))
            for c in batch:
                c = int(c)
                budget -= 1
                if c in seen or not self._is_available(c, round_idx):
                    continue
                seen.add(c)
                chosen.append(c)
                if len(chosen) == k:
                    break
        if len(chosen) < k:
            mask = self.availability_mask(round_idx)
            for c in rng.permutation(P):
                c = int(c)
                if mask[c] and c not in seen:
                    seen.add(c)
                    chosen.append(c)
                    if len(chosen) == k:
                        break
        return chosen

    def note_residual(self, cid, mass: float) -> None:
        self.residual_mass[int(cid)] = float(mass)

    # ------------------------------------------------------------------
    # checkpoint protocol (JSON-safe, matches driver/channel convention)
    def export_state(self) -> dict:
        nz = np.nonzero(self.residual_mass)[0]
        return {
            "population": self.population,
            "seed": self.seed,
            "churn_round": self._churn_round,
            "dead": sorted([int(c), int(r)] for c, r in self._dead.items()),
            "residual": [[int(c), repr(float(self.residual_mass[c]))]
                         for c in nz],
        }

    def restore_state(self, st: dict) -> None:
        if int(st["population"]) != self.population:
            raise ValueError(
                f"fleet population mismatch: state has "
                f"{st['population']}, table has {self.population}")
        self.seed = int(st["seed"])
        self._churn_round = int(st["churn_round"])
        self._dead = {int(c): int(r) for c, r in st["dead"]}
        self.residual_mass[:] = 0.0
        for c, m in st["residual"]:
            self.residual_mass[int(c)] = float(m)
