"""FaultPlan — deterministic, seeded device kill/rejoin schedules for the
RoundDriver (plus a random-process generator), the churn model the
production-ops roadmap item asks for.

A plan is a set of round-indexed events:

    kill    the device dies. ``at=None`` kills it before the round's
            dispatch (it is filtered from the cohort and any in-flight
            straggler work from earlier rounds is abandoned at the
            current clock); ``at`` in [0, 1] kills it MID-FLIGHT — the
            kill instant interpolates between the round's dispatch clock
            and the round's last fresh commit estimate, so the device's
            freshly dispatched work is torn down while its transfers are
            on the wire.
    rejoin  the device comes back before the round's dispatch under a
            FRESH identity (the driver bumps its incarnation counter, so
            a stale upload from the dead incarnation can never
            double-count), with its quarantined error-feedback residuals
            either restored or discarded per ``residual_policy``.

Failure semantics on kill (enforced by ``RoundDriver._kill``):

  * in-flight ``FluidLink`` flows are abandoned at the kill instant —
    bytes already drained stay drained (survivor schedules before the
    kill are untouched), the undelivered remainder is metered as
    abandoned and the capacity it held is released;
  * queued/running server work follows ``server_policy``: ``'cancel'``
    frees the slot at the kill instant, ``'orphan'`` lets an
    already-fed backward run to completion (occupying its slot) with
    the result dropped;
  * the device's error-feedback residuals are quarantined on the
    channel; on rejoin they are restored (``residual_policy='restore'``)
    or discarded with their L2 mass metered (``'discard'``);
  * every work item (aggregation-window key) the device contributes to
    that has not yet committed is abandoned exactly once — the driver's
    exactly-once ledger guarantees commits + abandons == dispatches.

See core/README.md §Failure semantics for the lifecycle diagram.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional

import numpy as np

KINDS = ("kill", "rejoin")
SERVER_POLICIES = ("cancel", "orphan")
RESIDUAL_POLICIES = ("restore", "discard")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    round: int                 # dispatch round the event applies to
    cid: object                # device id
    kind: str                  # 'kill' | 'rejoin'
    at: Optional[float] = None  # kill only: None = before dispatch;
    #                          # fraction in [0, 1] = mid-flight instant

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"fault kind {self.kind!r}; known: {KINDS}")
        if self.round < 0:
            raise ValueError(f"fault round must be >= 0: {self.round}")
        if self.at is not None:
            if self.kind != "kill":
                raise ValueError("'at' only applies to kill events")
            if not 0.0 <= self.at <= 1.0:
                raise ValueError(f"kill 'at' must be in [0, 1]: {self.at}")


class FaultPlan:
    """An immutable kill/rejoin schedule plus the two recovery policies.

    ``events`` may arrive in any order; they are applied per round in
    (round, cid) order with rejoins before kills, so a same-round
    rejoin+kill means the device flaps within one round
    deterministically.
    """

    def __init__(self, events=(), *, server_policy: str = "cancel",
                 residual_policy: str = "restore"):
        if server_policy not in SERVER_POLICIES:
            raise ValueError(f"server_policy {server_policy!r}; "
                             f"known: {SERVER_POLICIES}")
        if residual_policy not in RESIDUAL_POLICIES:
            raise ValueError(f"residual_policy {residual_policy!r}; "
                             f"known: {RESIDUAL_POLICIES}")
        self.server_policy = server_policy
        self.residual_policy = residual_policy
        evs = [e if isinstance(e, FaultEvent) else FaultEvent(**e)
               for e in events]
        self._by_round: dict = {}
        order = {"rejoin": 0, "kill": 1}
        for e in sorted(evs, key=lambda e: (e.round, order[e.kind],
                                            str(e.cid))):
            self._by_round.setdefault(e.round, []).append(e)
        self.events = tuple(e for r in sorted(self._by_round)
                            for e in self._by_round[r])

    def __len__(self):
        return len(self.events)

    def for_round(self, r: int) -> tuple:
        return tuple(self._by_round.get(r, ()))

    # ------------------------------------------------------ generation
    @classmethod
    def random(cls, cids, rounds: int, *, seed: int = 0,
               kill_prob: float = 0.1, rejoin_prob: float = 0.5,
               mid_flight_frac: float = 0.5,
               server_policy: str = "cancel",
               residual_policy: str = "restore") -> "FaultPlan":
        """The random-process mode: per round, each alive device dies
        with ``kill_prob`` (a ``mid_flight_frac`` share of kills strike
        mid-flight at a uniform fraction of the round, the rest before
        dispatch) and each dead device rejoins with ``rejoin_prob``.
        Fully determined by ``seed`` — the same draw stream regardless
        of what the driver does with the events."""
        if not 0.0 <= kill_prob <= 1.0:
            raise ValueError(f"kill_prob must be in [0, 1]: {kill_prob}")
        if not 0.0 <= rejoin_prob <= 1.0:
            raise ValueError(
                f"rejoin_prob must be in [0, 1]: {rejoin_prob}")
        rng = np.random.default_rng(seed)
        cids = list(cids)
        dead: set = set()
        events = []
        for r in range(rounds):
            for cid in cids:
                if cid in dead:
                    if rng.random() < rejoin_prob:
                        events.append(FaultEvent(r, cid, "rejoin"))
                        dead.discard(cid)
                elif rng.random() < kill_prob:
                    at = (float(rng.uniform(0.0, 1.0))
                          if rng.random() < mid_flight_frac else None)
                    events.append(FaultEvent(r, cid, "kill", at=at))
                    dead.add(cid)
        return cls(events, server_policy=server_policy,
                   residual_policy=residual_policy)

    # -------------------------------------------------------------- io
    def to_dict(self) -> dict:
        return {"server_policy": self.server_policy,
                "residual_policy": self.residual_policy,
                "events": [dataclasses.asdict(e) for e in self.events]}

    @classmethod
    def from_dict(cls, d: dict) -> "FaultPlan":
        return cls(d.get("events", ()),
                   server_policy=d.get("server_policy", "cancel"),
                   residual_policy=d.get("residual_policy", "restore"))

    def to_file(self, path: str):
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1)

    @classmethod
    def from_file(cls, path: str) -> "FaultPlan":
        with open(path) as f:
            return cls.from_dict(json.load(f))
