"""Device heterogeneity simulation — Table 1 grid + the Eq. 1 round clock.

    T = (2|Wc| + 2 p q) / R + Fc / Comp_c + Fs / Comp_s          (Eq. 1)

|Wc| is the client portion size (elements), q the per-sample feature size
at the cut, p the local sample count this round, Fc/Fs the client/server
fwd+bwd FLOPs. Comm overhead (Table 3's "Comm." column) counts model
down+upload and feature/gradient exchange.

Unit convention follows the paper's: sizes in elements, rates in
elements/sec, FLOPS in FLOP/sec — the Table 1 magnitudes reproduce the
paper's regime directly.
"""
from __future__ import annotations

import dataclasses
import itertools

import numpy as np

# Table 1
FLOPS_SETTINGS = {"low": 5e9, "mid": 1e10, "high": 2e10}
RATE_SETTINGS = {"low": 1e6, "mid": 2e6, "high": 5e6}
SERVER_FLOPS = 5e10
SERVER_RATE = 1e7

# repro.comm byte convention: rates stay in Table-1 elements/s; byte
# accounting treats one fp32 element as 4 bytes (comm/README.md).
BYTES_PER_ELEM = 4.0

# Phase split of the client fwd+bwd FLOPs Fc: the forward pass (before
# the feature upload) is ~1/3, the backward (after the gradient
# download) ~2/3 — the standard bwd ≈ 2x fwd accounting that
# utils/flops.py already uses for Fc itself.
CLIENT_FWD_FRAC = 1.0 / 3.0


@dataclasses.dataclass(frozen=True)
class Device:
    cid: int
    comp: float                    # FLOP/s
    rate: float                    # elements/s


def make_device_grid(n_devices: int, seed: int = 0,
                     composition=None) -> list:
    """The paper's 9 device kinds = 3 FLOPS x 3 transfer rates (Table 1),
    assigned round-robin (uncorrelated, as in §5.1). `composition` can
    reweight qualities, e.g. {'high': 5, 'mid': 3, 'low': 2} (Fig. 6)."""
    rng = np.random.default_rng(seed)
    if composition is None:
        kinds = list(itertools.product(FLOPS_SETTINGS, RATE_SETTINGS))
        picks = [kinds[i % len(kinds)] for i in range(n_devices)]
    else:
        quals = list(composition)
        weights = np.array([composition[q] for q in quals], float)
        weights /= weights.sum()
        fq = rng.choice(quals, size=n_devices, p=weights)
        rq = rng.choice(quals, size=n_devices, p=weights)
        picks = list(zip(fq, rq))
    rng.shuffle(picks)
    return [Device(cid=i, comp=FLOPS_SETTINGS[f], rate=RATE_SETTINGS[r])
            for i, (f, r) in enumerate(picks)]


@dataclasses.dataclass
class RoundCost:
    time: float = 0.0              # wall (max over devices)
    comm: float = 0.0              # total elements transferred
    device_times: dict = dataclasses.field(default_factory=dict)


def device_round_time_bytes(dev: Device, *, comm_bytes: float, fc: float,
                            fs: float, rate: float = None) -> float:
    """Eq. 1 with channel-metered payloads: comm_bytes is the full wire
    traffic for this device-round (2|Wc| dispatch + encoded features +
    encoded gradients), ``rate`` the link model's elements/s at the
    current clock (None -> the device's static Table-1 rate)."""
    r = (dev.rate if rate is None else rate) * BYTES_PER_ELEM
    return comm_bytes / r + fc / dev.comp + fs / SERVER_FLOPS


def fedavg_round_time(dev: Device, *, w_size: float, p: int,
                      f_full: float) -> float:
    """FedAvg baseline: full model both ways, all compute on device."""
    return 2.0 * w_size / dev.rate + p * f_full / dev.comp


def fedavg_round_time_bytes(dev: Device, *, comm_bytes: float, p: int,
                            f_full: float, rate: float = None) -> float:
    """FedAvg round time from channel-priced model-leg bytes (the
    compressed-FedAvg baseline; fp32 bytes reproduce fedavg_round_time
    exactly — both scale by powers of two)."""
    r = (dev.rate if rate is None else rate) * BYTES_PER_ELEM
    return comm_bytes / r + p * f_full / dev.comp


def fedavg_round_comm_bytes(*, w_size: float) -> float:
    return 2.0 * w_size * BYTES_PER_ELEM
