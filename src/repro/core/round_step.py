"""Fused SPMD S²FL round step — the pod-scale form of Algorithm 2.

Mapping (DESIGN.md §2): the global batch dim hosts the x participating
device cohorts (data-parallel shards). One jitted step performs:

  client-half forward  (batch sharded over `data`)
  balance permutation  (jnp.take over the global batch -> all-to-all; this
                        IS the paper's feature upload + Eq.2 regroup)
  per-group server half (vmap over G groups = G server-side copies)
  combined loss (Eq. 3), grad                     (VJP of the permutation
                        = the paper's gradient return, Step 7)
  SGD update; XLA's data-axis psum of grads is the E=1 fusion of per-copy
  updates + Algorithm-1 weighted aggregation (equal cohort weights).

Equivalence with the host engine at E=1 is asserted in tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.api import SplitModel
from repro.models.sharding import batch_spec, model_param_specs


def make_s2fl_loss(cfg, split: int, n_groups: int, dp_axes=None,
                   group_members: int = 1):
    """dp_axes: mesh axes the batch shards over (enables explicit sharding
    constraints around the balance permutation at pod scale; None for
    host/test execution). group_members: clients (cohorts) per balance
    group — Eq. 3 sums per-client losses, so the fused per-group CE mean
    is scaled by the member count (engine-equivalence tested)."""
    model = SplitModel(cfg)

    def csts(x, spec):
        if dp_axes is None:
            return x
        return jax.lax.with_sharding_constraint(x, spec)

    compute_dtype = jnp.dtype(cfg.dtype)

    @jax.custom_vjp
    def _grad_cast(x):
        return x

    def _gc_fwd(x):
        return x, None

    def _gc_bwd(_, g):
        # keep the permutation-backward collective in the compute dtype
        # (otherwise the scatter-add accumulates f32 — 2x ICI bytes)
        return (g.astype(compute_dtype),)

    _grad_cast.defvjp(_gc_fwd, _gc_bwd)

    def loss_fn(params, batch):
        feats = model.client_forward(params, batch, split, train=True)
        h = _grad_cast(feats["h"])
        h = jnp.take(h, batch["perm"], axis=0)               # all-to-all
        labels = jnp.take(batch["labels"], batch["perm"], axis=0)
        tokens = jnp.take(batch["tokens"], batch["perm"], axis=0)
        B = h.shape[0]
        gb = B // n_groups
        hg = h.reshape(n_groups, gb, *h.shape[1:])
        lg = labels.reshape(n_groups, gb, *labels.shape[1:])
        tg = tokens.reshape(n_groups, gb, *tokens.shape[1:])
        # keep the per-group batch dim on the data axes through the
        # permutation (otherwise SPMD replicates the server half)
        hg = csts(hg, P(None, dp_axes, *([None] * (h.ndim - 1))))
        lg = csts(lg, P(None, dp_axes, *([None] * (labels.ndim - 1))))
        tg = csts(tg, P(None, dp_axes, *([None] * (tokens.ndim - 1))))

        def group_loss(hh, ll, tt):
            l, _ = model.server_loss(
                params, {"h": hh, "aux": jnp.zeros((), jnp.float32)},
                {"tokens": tt, "labels": ll}, split, train=True)
            return l

        losses = jax.vmap(group_loss)(hg, lg, tg)            # G copies
        return losses.mean() * group_members + feats["aux"]

    return loss_fn


def make_s2fl_train_step(cfg, split: int, n_groups: int, lr: float,
                         dp_axes=None, group_members: int = 1):
    loss_fn = make_s2fl_loss(cfg, split, n_groups, dp_axes=dp_axes,
                             group_members=group_members)

    def step(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params = jax.tree.map(
            lambda w, g: (w - lr * g.astype(w.dtype)).astype(w.dtype),
            params, grads)
        return params, loss

    return step


def train_step_shardings(cfg, mesh, batch_abstract):
    """(in_shardings, out_shardings) for jax.jit over (params, batch)."""
    pspecs = model_param_specs(cfg, mesh)
    bspecs = {}
    for k, v in batch_abstract.items():
        if k == "perm":
            bspecs[k] = P(None)
        else:
            bspecs[k] = batch_spec(mesh, v.ndim, batch_size=v.shape[0])
    to_sh = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                   is_leaf=lambda x: isinstance(x, P))
    in_sh = (to_sh(pspecs), to_sh(bspecs))
    out_sh = (to_sh(pspecs), NamedSharding(mesh, P()))
    return in_sh, out_sh
