"""Adaptive sliding model split strategy (§3.1).

The Fed Server maintains a **client time table**: for every (client,
split-point) pair, the measured wall time of a full training round with
that client model portion. The first K rounds are a warm-up that traverses
all K split points (all clients use the same split in a warm-up round).
Afterwards, each round:

  1. collect the participating clients' recorded times for every split
     (x * K values), take the MEDIAN;
  2. each client gets the split whose recorded time is closest to the
     median (stragglers get small portions, fast devices big ones);
  3. on round completion, the table is updated with the observed time
     (EMA so drifting device load is tracked).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.split import SplitPlan


@dataclasses.dataclass
class ClientTimeTable:
    """times[cid][split] = EMA of observed round times."""
    ema: float = 0.5

    def __post_init__(self):
        self._t: dict = {}

    def update(self, cid, split: int, t: float):
        d = self._t.setdefault(cid, {})
        d[split] = (1 - self.ema) * d[split] + self.ema * t \
            if split in d else t

    def get(self, cid, split: int):
        return self._t.get(cid, {}).get(split)

    def known_splits(self, cid):
        return sorted(self._t.get(cid, {}))


class SlidingSplitScheduler:
    def __init__(self, plan: SplitPlan, ema: float = 0.5, forecast=None):
        self.plan = plan
        self.table = ClientTimeTable(ema=ema)
        self.round = 0
        # optional predictive hook (RoundDriver wires it when
        # predictive=True): forecast(cid, split, ema_time) -> predicted
        # round time with the link model's rate at the projected
        # completion window, None -> trust the EMA entry.
        self.forecast = forecast

    def _time(self, cid, split: int):
        """Candidate time for (cid, split): the EMA table entry, passed
        through the forecast hook when one is installed."""
        t = self.table.get(cid, split)
        if t is None:
            return None
        if self.forecast is not None:
            ft = self.forecast(cid, split, t)
            if ft is not None:
                return float(ft)
        return t

    @property
    def warming_up(self) -> bool:
        return self.round < self.plan.k

    def warmup_split(self) -> int:
        """§3.1: in the first K rounds the Fed Server sends the same split
        to ALL devices (the warm-up populates the whole time table; the
        engine/simulator observes every device's Eq.-1 time during these
        rounds, not just the sampled participants')."""
        return self.plan.split_points[self.round % self.plan.k]

    def select(self, participants) -> dict:
        """-> {cid: split} for this round."""
        if self.warming_up:
            s = self.warmup_split()
            return {c: s for c in participants}
        t = self._candidate_times(participants)
        times = [v for v in t.values() if v is not None]
        if not times:                       # nothing measured yet: smallest
            return {c: self.plan.smallest() for c in participants}
        median = float(np.median(times))
        out = {}
        for c in participants:
            known = [(s, t[c, s]) for s in self.plan.split_points
                     if t[c, s] is not None]
            if not known:
                out[c] = self.plan.smallest()
                continue
            out[c] = min(known, key=lambda st: abs(st[1] - median))[0]
        return out

    def _candidate_times(self, participants) -> dict:
        """{(cid, split): time-or-None} — one _time() evaluation per
        pair (the predictive forecast prices a trace integral per call,
        so selects must not re-query the same candidate)."""
        return {(c, s): self._time(c, s) for c in participants
                for s in self.plan.split_points}

    def observe(self, cid, split: int, t: float):
        self.table.update(cid, split, t)

    def end_round(self):
        self.round += 1

    # ------------------------------------------------- checkpoint state
    def export_state(self) -> dict:
        """Round counter + the full EMA time table, JSON-safe (int-keyed
        dicts as pair-lists; floats round-trip bit-exactly)."""
        return {"round": self.round,
                "table": [[cid, sorted(d.items())] for cid, d
                          in sorted(self.table._t.items(),
                                    key=lambda kv: str(kv[0]))]}

    def restore_state(self, st: dict):
        self.round = int(st["round"])
        self.table._t = {cid: {int(s): float(t) for s, t in d}
                         for cid, d in st["table"]}


class MinTimeScheduler(SlidingSplitScheduler):
    """BEYOND-PAPER variant: after warm-up each device picks the split
    minimizing ITS OWN recorded time, instead of matching the median.

    Rationale: the round wall-clock is max_i T_i, and per-device argmin
    greedily minimizes every T_i, hence the max — median matching can
    deliberately slow fast devices AND pick a slow split for stragglers
    whose time curve is non-monotone in split size (small models with
    large early feature maps, e.g. ResNet8/MobileNet — see
    EXPERIMENTS.md §Perf-scheduler). Equalization (the paper's stated
    goal) is a side effect of lowering everyone's time toward the same
    floor, not an objective worth paying wall-clock for."""

    def select(self, participants) -> dict:
        if self.warming_up:
            return super().select(participants)
        t = self._candidate_times(participants)
        out = {}
        for c in participants:
            known = [(s, t[c, s]) for s in self.plan.split_points
                     if t[c, s] is not None]
            if not known:
                out[c] = self.plan.smallest()
            else:
                out[c] = min(known, key=lambda st: st[1])[0]
        return out


class FixedSplitScheduler:
    """SFL baseline / S²FL+B ablation: everyone trains the largest client
    portion every round (the paper's SFL trains Wc_3)."""

    def __init__(self, plan: SplitPlan, split: int | None = None):
        self.plan = plan
        self.split = split if split is not None else plan.largest()
        self.round = 0
        self.table = ClientTimeTable()

    @property
    def warming_up(self) -> bool:
        return False

    def select(self, participants):
        return {c: self.split for c in participants}

    def observe(self, cid, split, t):
        self.table.update(cid, split, t)

    def end_round(self):
        self.round += 1

    export_state = SlidingSplitScheduler.export_state
    restore_state = SlidingSplitScheduler.restore_state
