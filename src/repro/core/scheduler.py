"""Adaptive sliding model split strategy (§3.1).

The Fed Server maintains a **client time table**: for every (client,
split-point) pair, the measured wall time of a full training round with
that client model portion. The first K rounds are a warm-up that traverses
all K split points (all clients use the same split in a warm-up round).
Afterwards, each round:

  1. collect the participating clients' recorded times for every split
     (x * K values), take the MEDIAN;
  2. each client gets the split whose recorded time is closest to the
     median (stragglers get small portions, fast devices big ones);
  3. on round completion, the table is updated with the observed time
     (EMA so drifting device load is tracked).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.split import SplitPlan


@dataclasses.dataclass
class ClientTimeTable:
    """times[cid][split] = EMA of observed round times."""
    ema: float = 0.5

    def __post_init__(self):
        self._t: dict = {}

    def update(self, cid, split: int, t: float):
        d = self._t.setdefault(cid, {})
        d[split] = (1 - self.ema) * d[split] + self.ema * t \
            if split in d else t

    def get(self, cid, split: int):
        return self._t.get(cid, {}).get(split)

    def known_splits(self, cid):
        return sorted(self._t.get(cid, {}))


class SlidingSplitScheduler:
    def __init__(self, plan: SplitPlan, ema: float = 0.5, forecast=None):
        self.plan = plan
        self.table = ClientTimeTable(ema=ema)
        self.round = 0
        # optional predictive hook (RoundDriver wires it when
        # predictive=True): forecast(cid, split, ema_time) -> predicted
        # round time with the link model's rate at the projected
        # completion window, None -> trust the EMA entry.
        self.forecast = forecast

    def _time(self, cid, split: int):
        """Candidate time for (cid, split): the EMA table entry, passed
        through the forecast hook when one is installed."""
        t = self.table.get(cid, split)
        if t is None:
            return None
        if self.forecast is not None:
            ft = self.forecast(cid, split, t)
            if ft is not None:
                return float(ft)
        return t

    @property
    def warming_up(self) -> bool:
        return self.round < self.plan.k

    def warmup_split(self) -> int:
        """§3.1: in the first K rounds the Fed Server sends the same split
        to ALL devices (the warm-up populates the whole time table; the
        engine/simulator observes every device's Eq.-1 time during these
        rounds, not just the sampled participants')."""
        return self.plan.split_points[self.round % self.plan.k]

    def select(self, participants) -> dict:
        """-> {cid: split} for this round."""
        if self.warming_up:
            s = self.warmup_split()
            return {c: s for c in participants}
        t = self._candidate_times(participants)
        times = [v for v in t.values() if v is not None]
        if not times:                       # nothing measured yet: smallest
            return {c: self.plan.smallest() for c in participants}
        median = float(np.median(times))
        out = {}
        for c in participants:
            known = [(s, t[c, s]) for s in self.plan.split_points
                     if t[c, s] is not None]
            if not known:
                out[c] = self.plan.smallest()
                continue
            out[c] = min(known, key=lambda st: abs(st[1] - median))[0]
        return out

    def _candidate_times(self, participants) -> dict:
        """{(cid, split): time-or-None} — one _time() evaluation per
        pair (the predictive forecast prices a trace integral per call,
        so selects must not re-query the same candidate)."""
        return {(c, s): self._time(c, s) for c in participants
                for s in self.plan.split_points}

    def observe(self, cid, split: int, t: float):
        self.table.update(cid, split, t)

    def end_round(self):
        self.round += 1

    # ------------------------------------------------- checkpoint state
    def export_state(self) -> dict:
        """Round counter + the full EMA time table, JSON-safe (int-keyed
        dicts as pair-lists; floats round-trip bit-exactly)."""
        return {"round": self.round,
                "table": [[cid, sorted(d.items())] for cid, d
                          in sorted(self.table._t.items(),
                                    key=lambda kv: str(kv[0]))]}

    def restore_state(self, st: dict):
        self.round = int(st["round"])
        self.table._t = {cid: {int(s): float(t) for s, t in d}
                         for cid, d in st["table"]}


class MinTimeScheduler(SlidingSplitScheduler):
    """BEYOND-PAPER variant: after warm-up each device picks the split
    minimizing ITS OWN recorded time, instead of matching the median.

    Rationale: the round wall-clock is max_i T_i, and per-device argmin
    greedily minimizes every T_i, hence the max — median matching can
    deliberately slow fast devices AND pick a slow split for stragglers
    whose time curve is non-monotone in split size (small models with
    large early feature maps, e.g. ResNet8/MobileNet — see
    EXPERIMENTS.md §Perf-scheduler). Equalization (the paper's stated
    goal) is a side effect of lowering everyone's time toward the same
    floor, not an objective worth paying wall-clock for."""

    def select(self, participants) -> dict:
        if self.warming_up:
            return super().select(participants)
        t = self._candidate_times(participants)
        out = {}
        for c in participants:
            known = [(s, t[c, s]) for s in self.plan.split_points
                     if t[c, s] is not None]
            if not known:
                out[c] = self.plan.smallest()
            else:
                out[c] = min(known, key=lambda st: st[1])[0]
        return out


class JointKnobScheduler(MinTimeScheduler):
    """AdaptSFL/HASFL-style joint tuning: the candidate space is the
    cross product of split points and per-client batch FRACTIONS, and
    each device picks the pair minimizing its forecast time — with a
    data-preserving tie rule: among candidates within
    ``frac_tolerance`` of the fastest, the LARGEST batch fraction wins,
    so a marginal time win never silently sacrifices training samples.

    Pricing a fraction needs a forecaster that understands how compute
    and payload scale with the sample count; the driver installs
    ``forecast_frac(cid, split, ema_t, frac)`` in resource-aware mode
    (``core/control.py``). Without it, fractions are not priced and the
    selection degenerates to MinTime at full batch — the knob only
    activates alongside a physics-aware forecast, never on a blind EMA.

    ``selected_fracs`` ({cid: frac}, rebuilt by every ``select``) is
    the consumers' surface: the driver wires it into the cost model's
    ``frac_of`` hook and the engine scales its real batches with it."""

    def __init__(self, plan: SplitPlan, ema: float = 0.5, forecast=None,
                 batch_fracs=(1.0, 0.75, 0.5),
                 frac_tolerance: float = 0.1):
        super().__init__(plan, ema=ema, forecast=forecast)
        fracs = sorted({float(f) for f in batch_fracs}, reverse=True)
        if not fracs or any(not 0.0 < f <= 1.0 for f in fracs):
            raise ValueError(f"batch fracs must be in (0, 1]: "
                             f"{batch_fracs}")
        if frac_tolerance < 0.0:
            raise ValueError(f"frac_tolerance must be >= 0: "
                             f"{frac_tolerance}")
        self.batch_fracs = tuple(fracs)
        self.frac_tolerance = float(frac_tolerance)
        self.selected_fracs: dict = {}
        # installed by the driver in resource-aware mode:
        # (cid, split, ema_t, frac) -> predicted time, None = unpriced
        self.forecast_frac = None

    def _frac_time(self, cid, split, t, frac):
        if self.forecast_frac is not None:
            ft = self.forecast_frac(cid, split, t, frac)
            if ft is not None:
                return float(ft)
        return None

    def select(self, participants) -> dict:
        # selection must see the UNSCALED p_of: consumers read the
        # previous round's fracs through this dict, so clear it first
        self.selected_fracs = {}
        if self.warming_up or self.forecast_frac is None:
            out = super().select(participants)
            for c in participants:
                self.selected_fracs[c] = self.batch_fracs[0]
            return out
        t = self._candidate_times(participants)
        out = {}
        for c in participants:
            cands = []
            for s in self.plan.split_points:
                if t[c, s] is None:
                    continue
                for f in self.batch_fracs:
                    tf = self._frac_time(c, s, t[c, s], f)
                    cands.append((s, f, t[c, s] if tf is None else tf))
            if not cands:
                out[c] = self.plan.smallest()
                self.selected_fracs[c] = self.batch_fracs[0]
                continue
            best = min(tt for _, _, tt in cands)
            ok = [cand for cand in cands
                  if cand[2] <= best * (1.0 + self.frac_tolerance)]
            s, f, _ = min(ok, key=lambda cand: (-cand[1], cand[2]))
            out[c] = s
            self.selected_fracs[c] = f
        return out


class FixedSplitScheduler:
    """SFL baseline / S²FL+B ablation: everyone trains the largest client
    portion every round (the paper's SFL trains Wc_3)."""

    def __init__(self, plan: SplitPlan, split: int | None = None):
        self.plan = plan
        self.split = split if split is not None else plan.largest()
        self.round = 0
        self.table = ClientTimeTable()

    @property
    def warming_up(self) -> bool:
        return False

    def select(self, participants):
        return {c: self.split for c in participants}

    def observe(self, cid, split, t):
        self.table.update(cid, split, t)

    def end_round(self):
        self.round += 1

    export_state = SlidingSplitScheduler.export_state
    restore_state = SlidingSplitScheduler.restore_state
