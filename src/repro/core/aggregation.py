"""Algorithm 1 — three-portion model aggregation.

Because the split slides per device, a given layer (segment) may have been
trained on some devices' clients and, for the others, inside their group's
server-side copy. For every segment of the full model W:

    W[seg] = sum_i |D_i| * source_i[seg]  /  sum_i |D_i|

where source_i = client params of device i if the segment lies in its
client portion, else the server copy of device i's group — exactly lines
3–17 of Algorithm 1 (weights are data sizes |D_i|).
"""
from __future__ import annotations

import dataclasses

from repro.models.api import SplitModel
from repro.utils.tree import get_subtree, set_subtree, tree_weighted_sum


@dataclasses.dataclass
class ClientState:
    cid: int
    params: dict                   # trained client-side params (full tree)
    split: int
    data_size: float
    group: int


def aggregate(model: SplitModel, clients: list, server_copies: dict) -> dict:
    """clients: list[ClientState]; server_copies: {group_id: params}.
    Returns the aggregated full model W."""
    assert clients, "no clients to aggregate"
    out = clients[0].params        # template for reassembly
    for name, path in model.segments():
        subs, weights = [], []
        for c in clients:
            src = (c.params if name in model.client_segments(c.split)
                   else server_copies[c.group])
            subs.append(get_subtree(src, path))
            weights.append(c.data_size)
        out = set_subtree(out, path, tree_weighted_sum(subs, weights))
    return out


def fedavg_aggregate(params_list, weights):
    """Plain FedAvg weighted average (baseline)."""
    return tree_weighted_sum(params_list, weights)
