"""Resource-aware control plane: price candidate splits against the
simulator's actual physics instead of a naive link-rate model.

The blind predictive forecast (``AnalyticCost.forecast_time``) sees one
number — the link's mean rate over the projected window, capped by
``capacity / cohort`` — and nothing else. But the world it schedules
into is stateful (PR 5–8): a finite FIFO server queue, duplex
``FluidLink`` contention with cross-window in-flight carry, re-dispatch
gating, and error-feedback residual state a re-split would discard.
This module closes the loop:

``ResourceView``
    a READ-ONLY window onto the live ``RoundDriver`` state — server
    queue depth (``_ServerQueue.depth_at``), per-direction link backlog
    and live-flow counts (``FluidLink.backlog_at``), each device's own
    draining downloads (``_dev_busy``), its last dispatched split, its
    error-feedback residual mass, and the observed per-device
    round-time history (``observe.history.RoundTimeTracker``). Queries
    that re-solve a fluid schedule are cached per (round, clock) so a
    round's whole candidate sweep pays for one solve.

``resource_aware_forecast``
    the forecast formula (see core/README.md §Control plane):

        T(s) = gate_wait                      # own draining download
             + t_pre(s)                       # Wc leg + client fwd
             + up(s) / min(r, C_up/(L+A)) + B_up / C_up
             + ahead · (d̄ + t_srv(s)) / slots + t_srv(s)
             + down(s) / min(r, C_dn/(L+A')) + B_dn / C_dn
             + behind · down(s) / C_dn        # downlink externality
             + t_post(s)                      # client bwd + Wc collect

    with r the link's mean rate over the projected horizon, L the
    cohort size, A/B the live-flow count and backlog bytes already
    draining on the shared link, ahead = depth + (L-1)/2 the jobs
    expected to share the server queue, and d̄ their mean live
    duration. The ``ahead · t_srv(s) / slots`` piece is marginal-cost
    (Pigouvian) pricing of the FIFO slot — the delay the candidate's
    own service time imposes on the jobs behind it — which is what
    makes a cohort of per-device argmins drain a contended server
    instead of piling onto it (every other live-state term is a
    split-independent constant that can never move an argmin). The
    symmetric ``behind · down(s) / C_dn`` term prices the shared-egress
    externality the same way: the candidate's dfx payload backlogs the
    behind = A' + (L-1)/2 flows draining the downlink with it. The
    horizon is learned from the observed round-time distribution: the
    tracker's (q_lo, EMA, q_hi) band is priced and the WORST case
    taken, so a fade inside the uncertainty band moves the selection
    before it is ever observed. A candidate split that differs from the
    device's last dispatched one additionally prices the error-feedback
    residual elements a re-split would discard as extra uplink bytes.

``AggregationController``
    AdaptSFL/HASFL-style aggregation-frequency tuning: deterministic
    successive probing over a small (quorum, staleness_cap) grid,
    locking the argmin-mean-round-time setting among candidates whose
    observed per-round loss delta does not regress more than
    ``loss_tol`` past the configured anchor's (the engine feeds each
    round's training loss via ``observe_loss`` — time-only scoring
    would happily lock a window that commits nothing). The driver
    applies it at round start under a safety rule (the cap never drops
    below the age of the oldest pending event, so the staleness
    invariant holds through a downward change).
"""
from __future__ import annotations

import math

from repro.comm.channel import MESSAGES_PER_ROUND
from repro.core.simulation import (BYTES_PER_ELEM, CLIENT_FWD_FRAC,
                                   SERVER_FLOPS)


class ResourceView:
    """Read-only view over a ``RoundDriver``'s live resource state.

    Never mutates anything: every query goes through observational
    methods (``depth_at``, ``backlog_at``) or plain attribute reads.
    Built by the driver when ``resource_aware=True`` and handed to the
    forecast; also usable directly (tests, diagnostics)."""

    def __init__(self, driver, history=None):
        self._drv = driver
        self.history = history
        self._cache_key = None     # (round, clock) the caches are for
        self._cache: dict = {}

    # ------------------------------------------------------ basic state
    @property
    def clock(self) -> float:
        return self._drv.clock

    @property
    def cohort_load(self) -> int:
        """Devices sharing the uplink this round (driver's ``_load``)."""
        return self._drv._load

    @property
    def gated(self) -> bool:
        return self._drv.gate_redispatch

    @property
    def server_slots(self) -> float:
        return self._drv.server_concurrency or math.inf

    def busy_until(self, cid) -> float:
        """When the device's own latest download finishes draining
        (0.0 = idle). With ``gate_redispatch`` its next upload cannot
        start before this."""
        return self._drv._dev_busy.get(cid, 0.0)

    def last_split(self, cid):
        """Split the device was last dispatched with (None = never)."""
        return self._drv._last_split.get(cid)

    def draining_flights(self, cid) -> list:
        """The device's own live flights (in-flight uploads/backwards/
        downloads from earlier windows)."""
        return [fl for fl in self._drv._flights.values()
                if fl.cid == cid]

    # ----------------------------------------------- cached link state
    def _cached(self, name, fn):
        key = (self._drv.round, self._drv.clock)
        if self._cache_key != key:
            self._cache_key = key
            self._cache = {}
        if name not in self._cache:
            self._cache[name] = fn()
        return self._cache[name]

    def server_depth(self) -> int:
        """Jobs arrived but unfinished on the server at the current
        clock (waiting + running)."""
        q = self._drv._srvq
        if q is None:
            return 0
        return self._cached("srv_depth",
                            lambda: q.depth_at(self._drv.clock))

    def server_mean_duration(self, default: float) -> float:
        """Mean duration of the jobs still live in the server queue —
        the queue-wait unit the forecast charges per job ahead
        (``default`` when the queue is empty or absent)."""
        q = self._drv._srvq
        if q is None or not q._live:
            return default
        def _mean():
            durs = [q._dur[j] for j in q._live]
            return sum(durs) / len(durs)
        return self._cached("srv_mean_dur", _mean)

    def uplink_backlog(self):
        """(live flow count, bytes still in flight) on the shared
        ingress at the current clock — (0, 0.0) when uncontended or
        before the first pipelined round."""
        return self._cached("up_backlog",
                            lambda: self._link_backlog(self._drv._uplink))

    def downlink_backlog(self):
        return self._cached("dn_backlog",
                            lambda: self._link_backlog(self._drv._downlink))

    def _link_backlog(self, link):
        if link is None or not link.contended or not len(link):
            return 0, 0.0
        return link.backlog_at(self._drv.clock)

    def uplink_utilization(self, t0: float, t1: float) -> float:
        link = self._drv._uplink
        return 0.0 if link is None else link.utilization(t0, t1)

    def downlink_utilization(self, t0: float, t1: float) -> float:
        link = self._drv._downlink
        return 0.0 if link is None else link.utilization(t0, t1)

    # -------------------------------------------------- channel signals
    def residual_elements(self, cid) -> float:
        """Error-feedback residual elements the device currently holds
        on the channel — the mass a re-split would discard (residuals
        reset on a cut-layer shape change)."""
        ch = getattr(self._drv.cost, "channel", None)
        if ch is None or not getattr(ch, "error_feedback", False):
            return 0.0
        fn = getattr(ch, "residual_elements_of", None)
        return 0.0 if fn is None else fn(cid)

    # ------------------------------------------------- learned horizon
    def horizon_band(self, cid, fallback: float):
        """(lo, mid, hi) forecast-horizon band for the device, learned
        from its observed round times; degrades to the flat
        ``fallback`` (the scheduler's EMA entry) before any history."""
        if self.history is not None:
            band = self.history.band(cid)
            if band is not None:
                return band
        h = max(float(fallback), 1e-9)
        return (h, h, h)


def resource_aware_forecast(view: ResourceView, cost, dev, split: int,
                            recorded: float, *, frac: float = 1.0,
                            ef_weight: float = 1.0):
    """Price one candidate (device, split[, batch fraction]) against the
    live resource state. Returns predicted seconds, or None when the
    cost model is not analytic (no ``cost(split)``/``channel`` surface —
    the caller then falls back to the blind forecast).

    ``frac`` scales the per-round sample count (the joint batch-size
    knob): compute terms and the feature payload scale with it, the
    model legs do not."""
    if not hasattr(cost, "cost") or getattr(cost, "channel", None) is None:
        return None
    c = cost.cost(split)
    ch = cost.channel
    cid = dev.cid
    p = cost.p_of(cid)
    if frac != 1.0:
        p = max(1.0, round(p * frac))
    clock = view.clock
    start = max(clock, view.busy_until(cid)) if view.gated else clock
    gate_wait = start - clock

    n_values = p * c["feat_size"]
    wc_leg = ch.estimate_dispatch_leg(c["wc_size"])
    up = ch.estimate_uplink_payload(n_values)
    down = ch.estimate_downlink_payload(n_values)
    # residual-aware re-split pricing: switching the cut layer resets
    # the device's error-feedback accumulators (shape change), so the
    # residual elements it holds are information that must cross the
    # wire again — charge them to the candidate's uplink
    last = view.last_split(cid)
    if last is not None and split != last:
        up += ef_weight * view.residual_elements(cid) * BYTES_PER_ELEM

    fc = p * c["fc"]
    t_srv = p * c["fs"] / SERVER_FLOPS
    # 2 messages ride each client-side phase; forecasts price the MEAN
    # latency (a future round's draw is unknown, all dists mean-preserve)
    lat2 = 0.5 * MESSAGES_PER_ROUND * ch.latency
    load = view.cohort_load
    slots = view.server_slots
    up_cap = cost.shared_uplink_bytes()
    dn_cap = cost.shared_downlink_bytes()
    n_up, up_backlog = view.uplink_backlog()
    n_dn, dn_backlog = view.downlink_backlog()
    # server wait: jobs already queued, plus the half-cohort expected to
    # arrive alongside this device inside the same window, each holding
    # a slot for one mean backward. The social term is marginal-cost
    # (Pigouvian) pricing of the FIFO slot: the candidate's own service
    # time delays every job queued behind it, and a cohort of selfish
    # per-device argmins only drains the bottleneck if each internalizes
    # that externality — without it every live-state term is a
    # split-independent constant that can never move an argmin
    srv_wait = 0.0
    srv_social = 0.0
    if not math.isinf(slots):
        ahead = view.server_depth() + 0.5 * max(load - 1, 0)
        srv_wait = ahead * view.server_mean_duration(t_srv) / slots
        srv_social = ahead * t_srv / slots
    # the symmetric downlink externality: the candidate's dfx transfer
    # occupies the shared egress for down/dn_cap seconds, backlogging
    # every flow draining behind it (live flows + the half-cohort
    # arriving inside the same window) — without this term a fat
    # downlink payload looks free to the per-device argmin exactly the
    # way an unpriced FIFO slot did
    dn_social = 0.0
    if not math.isinf(dn_cap):
        behind = n_dn + 0.5 * max(load - 1, 0)
        dn_social = behind * down / dn_cap

    lo, mid, hi = view.horizon_band(cid, recorded)
    worst = None
    for h in {lo, mid, hi}:
        rate = ch.mean_rate(dev, start, start + max(h, 1e-9)) \
            * BYTES_PER_ELEM
        up_rate, up_wait = rate, 0.0
        if not math.isinf(up_cap):
            up_rate = min(rate, up_cap / max(load + n_up, 1))
            up_wait = up_backlog / up_cap
        dn_rate, dn_wait = rate, 0.0
        if not math.isinf(dn_cap):
            dn_rate = min(rate, dn_cap / max(load + n_dn, 1))
            dn_wait = dn_backlog / dn_cap
        t = (gate_wait
             + lat2 + wc_leg / rate + CLIENT_FWD_FRAC * fc / dev.comp
             + up_wait + up / up_rate
             + srv_wait + srv_social + t_srv
             + dn_wait + dn_social + down / dn_rate
             + lat2 + wc_leg / rate
             + (1.0 - CLIENT_FWD_FRAC) * fc / dev.comp)
        if worst is None or t > worst:
            worst = t
    return worst


def default_knob_grid(quorum: float, staleness_cap: int):
    """Candidate (quorum, staleness_cap) settings for the aggregation
    controller, anchored on the configured pair: the configured setting
    probes first (ties go to it), then earlier-closing windows (lower
    quorum / extra staleness headroom) and a stricter near-sync one."""
    grid = [(quorum, staleness_cap)]
    for q, cap in ((max(0.25, quorum - 0.2), staleness_cap),
                   (quorum, staleness_cap + 1),
                   (min(1.0, quorum + 0.25), max(staleness_cap - 1, 0))):
        if (q, cap) not in grid:
            grid.append((q, cap))
    return tuple(grid)


class AggregationController:
    """Deterministic successive-probe tuner for the aggregation
    frequency: each candidate (quorum, staleness_cap) setting runs for
    ``probe_rounds`` rounds, its mean round time is recorded, and after
    the sweep the argmin setting locks in (first-probed wins ties, so
    the configured anchor is preferred at equal cost). No RNG, no wall
    clock — replays bit-exactly and checkpoints as flat lists.

    Round time alone is a trap: a loose quorum that commits almost
    nothing closes windows fast while learning stalls. When the caller
    also feeds the observed training loss (``observe_loss``, once per
    round), each probe accumulates its mean per-round loss *delta*, and
    at lock time any candidate whose mean delta regresses more than
    ``loss_tol`` past the anchor setting's (index 0 — the configured
    pair, never rejected) is disqualified before the time argmin runs.
    With no loss signal the behavior is exactly the time-only tuner."""

    def __init__(self, settings, probe_rounds: int = 4,
                 loss_tol: float = 0.25):
        settings = [(float(q), int(cap)) for q, cap in settings]
        if not settings:
            raise ValueError("need at least one (quorum, cap) setting")
        for q, cap in settings:
            if not 0.0 < q <= 1.0 or cap < 0:
                raise ValueError(f"bad knob setting ({q}, {cap})")
        self.settings = settings
        self.probe_rounds = int(probe_rounds)
        self.loss_tol = float(loss_tol)
        self._sums = [0.0] * len(settings)
        self._counts = [0] * len(settings)
        self._loss_sums = [0.0] * len(settings)
        self._loss_counts = [0] * len(settings)
        self._last_loss = None     # previous round's loss (delta base)
        self._last_probe = 0       # setting the last observed round ran
        self._i = 0
        self.locked = None         # index once the sweep finished
        self.rejected = ()         # indices disqualified on loss

    def current(self):
        """(quorum, staleness_cap) to run the next round with."""
        i = self.locked if self.locked is not None else self._i
        return self.settings[i]

    def observe(self, round_time: float):
        """Feed one round's duration under the current setting."""
        if self.locked is not None:
            return
        self._last_probe = self._i
        self._sums[self._i] += float(round_time)
        self._counts[self._i] += 1
        if self._counts[self._i] >= self.probe_rounds:
            if self._i + 1 < len(self.settings):
                self._i += 1
            else:
                self._lock()

    def observe_loss(self, loss):
        """Feed the round's observed training loss (call after the
        round's ``observe``; the delta vs the previous round accrues to
        the setting that round actually ran under). Non-finite losses
        are skipped — a NaN round neither poisons a probe nor resets
        the delta base unfairly: the base just carries forward."""
        loss = float(loss)
        if not math.isfinite(loss):
            return
        if self._last_loss is not None and self.locked is None:
            j = self._last_probe
            self._loss_sums[j] += loss - self._last_loss
            self._loss_counts[j] += 1
        self._last_loss = loss

    def _lock(self):
        means = [s / max(n, 1) for s, n in zip(self._sums, self._counts)]
        deltas = [ls / ln if ln else None
                  for ls, ln in zip(self._loss_sums, self._loss_counts)]
        anchor = deltas[0] if deltas[0] is not None else 0.0
        eligible = [j for j in range(len(means))
                    if j == 0 or deltas[j] is None
                    or deltas[j] - anchor <= self.loss_tol]
        self.rejected = tuple(j for j in range(len(means))
                              if j not in eligible)
        self.locked = min(eligible, key=lambda j: (means[j], j))

    def loss_delta_means(self):
        """Per-setting mean per-round loss delta (None = no signal)."""
        return [ls / ln if ln else None
                for ls, ln in zip(self._loss_sums, self._loss_counts)]

    # ------------------------------------------------- checkpoint state
    def export_state(self) -> dict:
        return {"settings": [[q, cap] for q, cap in self.settings],
                "probe_rounds": self.probe_rounds,
                "loss_tol": self.loss_tol,
                "sums": list(self._sums), "counts": list(self._counts),
                "loss_sums": [repr(x) for x in self._loss_sums],
                "loss_counts": list(self._loss_counts),
                "last_loss": (None if self._last_loss is None
                              else repr(self._last_loss)),
                "last_probe": self._last_probe,
                "rejected": list(self.rejected),
                "i": self._i, "locked": self.locked}

    def restore_state(self, st: dict):
        self.settings = [(float(q), int(cap)) for q, cap in st["settings"]]
        self.probe_rounds = int(st["probe_rounds"])
        n = len(self.settings)
        self.loss_tol = float(st.get("loss_tol", self.loss_tol))
        self._sums = [float(x) for x in st["sums"]]
        self._counts = [int(x) for x in st["counts"]]
        # pre-loss-awareness checkpoints restore as a time-only tuner
        self._loss_sums = [float(x) for x in st.get("loss_sums",
                                                    [0.0] * n)]
        self._loss_counts = [int(x) for x in st.get("loss_counts",
                                                    [0] * n)]
        last = st.get("last_loss")
        self._last_loss = None if last is None else float(last)
        self._last_probe = int(st.get("last_probe", 0))
        self.rejected = tuple(int(j) for j in st.get("rejected", ()))
        self._i = int(st["i"])
        self.locked = None if st["locked"] is None else int(st["locked"])
