"""Algorithm 2 — the S²FL round engine (plus SFL and FedAvg baselines and
the paper's ablation variants S²FL+{R,B,M,MB}).

This is the host-level engine: exact per-device client portions, per-group
server copies, E local SGD steps per round, Eq.-1 simulated clock, and
Algorithm-1 aggregation. The fused SPMD equivalent used at pod scale lives
in ``repro.core.round_step`` (E=1, documented equivalence, tested).

Workflow per round (Fig. 1 steps 1–9):
  1/2  scheduler picks Wc per device (client time table), W dispatched
  3/4  devices run client fwd, upload features + labels
  5    Main Server groups features (Eq. 2) and makes per-group Ws copies
  6    per-group combined loss, backward, Ws update
  7/8  feature gradients return, devices update Wc
  9    Fed Server aggregates (Algorithm 1)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import make_channel
from repro.configs.base import CommConfig, DriverConfig
from repro.core import simulation as sim
from repro.core.aggregation import ClientState, aggregate, fedavg_aggregate
from repro.core.balance import greedy_groups, label_histogram
from repro.core.driver import FedAvgCost, MeteredCost, RoundDriver
from repro.core.scheduler import FixedSplitScheduler, SlidingSplitScheduler
from repro.core.split import SplitPlan, default_plan
from repro.models.api import SplitModel
from repro.optim import sgd
from repro.utils import flops as flops_util


def _tree_stack(trees):
    """Stack a list of same-structure pytrees leaf-wise: (…)->(G, …)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _tree_index(tree, i):
    """Leaf-wise slice of a stacked pytree: (G, …)[i] -> (…)."""
    return jax.tree.map(lambda x: x[i], tree)


def _donate(*argnums):
    """jax ignores buffer donation on CPU (one warning per call site) —
    donate only on accelerator backends."""
    return argnums if jax.default_backend() != "cpu" else ()


@dataclasses.dataclass
class EngineConfig:
    mode: str = "s2fl"            # 's2fl' | 'sfl' | 'fedavg'
    use_balance: bool = True      # +B (False -> each device its own group)
    use_sliding: bool = True      # +M (False -> fixed largest split)
    scheduler: str = "median"     # 'median' (paper §3.1) | 'mintime'
                                  # | 'joint' (beyond-paper, scheduler.py)
    batch_fracs: tuple = ()       # 'joint' candidate batch fractions;
                                  # () -> (1.0, 0.75, 0.5)
    rounds: int = 50
    clients_per_round: int = 10
    local_steps: int = 1          # E
    batch_size: int = 32
    lr: float = 0.01
    group_size: int = 2           # devices per balance group
    split_k: int = 3
    seed: int = 0
    n_classes: int = 10
    # transport: codecs + link model for the cut-layer exchange
    # (repro.comm; fp32/static reproduces the seed's semantics, comm is
    # accounted in bytes — see comm/README.md)
    comm: CommConfig = dataclasses.field(default_factory=CommConfig)
    # round-loop execution: sync barrier vs semi-async event queue, and
    # predictive (link-forecasting) split selection — core/README.md
    driver: DriverConfig = dataclasses.field(default_factory=DriverConfig)
    # batched hot path (both default off: the seed path stays bit-exact).
    # fused_comm flushes each direction's whole cohort through ONE
    # jitted, donated call (comm/fused.py) — bytes metered bit-equal,
    # tensors ≤1e-6 vs the sequential chain. fused_server stacks
    # same-signature concurrent groups into one vmapped, donated server
    # step (losses/params may drift ~1e-4 from batched-kernel numerics).
    fused_comm: bool = False
    fused_server: bool = False


class S2FLEngine:
    """Drives FedAvg / SFL / S²FL over a federated dataset.

    data: {cid: {'x'|'tokens': ..., 'y'|'labels': ...}} host numpy arrays.
    """

    def __init__(self, model: SplitModel, data: dict, ecfg: EngineConfig,
                 devices: Optional[list] = None,
                 plan: Optional[SplitPlan] = None, recorder=None,
                 fault_plan=None):
        self.model = model
        self.data = data
        self.ecfg = ecfg
        self.rng = np.random.default_rng(ecfg.seed)
        self.plan = plan or default_plan(model.n_units, k=ecfg.split_k)
        # fleet mode (core/fleet.py): the population lives as (P,)
        # tables, cohorts are fleet-sampled, and the object grid is
        # never materialized — each fleet cid trains on the data shard
        # cid mod n_shards
        self.fleet = None
        fleet_size = int(getattr(ecfg.driver, "fleet_size", 0) or 0)
        if fleet_size and devices is None:
            from repro.core.fleet import Fleet
            self.fleet = Fleet.table1(
                fleet_size, seed=ecfg.seed,
                clusters=int(getattr(ecfg.driver, "clusters", 0)))
            self.devices = []
        else:
            self.devices = devices or sim.make_device_grid(len(data),
                                                           seed=ecfg.seed)
        self.dev_by_id = {d.cid: d for d in self.devices}
        self._shards = sorted(data)

        if ecfg.mode == "s2fl" and ecfg.use_sliding:
            if ecfg.scheduler == "mintime":
                from repro.core.scheduler import MinTimeScheduler
                self.scheduler = MinTimeScheduler(self.plan)
            elif ecfg.scheduler == "joint":
                from repro.core.scheduler import JointKnobScheduler
                self.scheduler = JointKnobScheduler(
                    self.plan,
                    batch_fracs=ecfg.batch_fracs or (1.0, 0.75, 0.5))
            else:
                self.scheduler = SlidingSplitScheduler(self.plan)
        else:
            self.scheduler = FixedSplitScheduler(self.plan)

        self.opt = sgd(ecfg.lr)
        self.params = model.init(jax.random.PRNGKey(ecfg.seed))
        self.channel = make_channel(ecfg.comm)
        # observability (observe/): one recorder feeds both the driver's
        # flight/window hooks and the channel's wire counters; None (the
        # default) keeps every hook site a dead branch
        self.recorder = recorder
        self.channel.recorder = recorder
        self.history = []          # per round dicts
        self._hists = {cid: self._client_hist(cid) for cid in data}
        self._key = jax.random.PRNGKey(ecfg.seed + 1)

        # the unified round loop (core/driver.py): the engine's rounds
        # are metered-cost driver rounds; clock/comm live on the driver
        dcfg = ecfg.driver
        if ecfg.mode == "fedavg":
            cost = FedAvgCost(
                lambda: flops_util.split_costs(self.model,
                                               self.model.n_units,
                                               seq_len=self._seq_len()),
                p_of=self._p_of, channel=self.channel)
        else:
            cost = MeteredCost(
                self.channel,
                lambda s: flops_util.split_costs(self.model, s,
                                                 seq_len=self._seq_len()),
                p_of=self._p_of)
        # the engine scales its REAL batches by the joint scheduler's
        # selected fracs (_batch_size_of feeds both _p_of and
        # _sample_batch), so the cost model's frac_of hook must stay
        # inert — a unit sentinel here stops the driver's auto-wiring
        # from scaling the already-scaled p a second time
        cost.frac_of = lambda cid: 1.0
        knobs = None
        if getattr(dcfg, "auto_knobs", False) \
                and dcfg.exec_mode == "semi_async":
            from repro.core.control import (AggregationController,
                                            default_knob_grid)
            knobs = AggregationController(
                default_knob_grid(dcfg.quorum, dcfg.staleness_cap))
        self.driver = RoundDriver(
            self.scheduler, cost, self.devices, mode=dcfg.exec_mode,
            staleness_cap=dcfg.staleness_cap, quorum=dcfg.quorum,
            predictive=dcfg.predictive, pipeline=dcfg.pipeline,
            server_concurrency=getattr(dcfg, "server_concurrency", 0),
            gate_redispatch=getattr(dcfg, "gate_redispatch", False),
            resource_aware=getattr(dcfg, "resource_aware", False),
            warmup_devices=[d for d in self.devices if d.cid in data],
            recorder=recorder, fault_plan=fault_plan,
            knob_controller=knobs, fleet=self.fleet,
            clusters=int(getattr(dcfg, "clusters", 0)),
            cluster_quorum=float(getattr(dcfg, "cluster_quorum", 1.0)))
        self._held = {}            # gid -> un-committed round results
        self._next_gid = 0

        # jit caches
        self._client_fwd = {}
        self._server_step = {}
        self._client_upd = {}
        self._fedavg_step = None

    # ------------------------------------------------------- timeline
    @property
    def clock(self) -> float:
        """Simulated Eq.-1 wall clock (owned by the RoundDriver)."""
        return self.driver.clock

    @property
    def comm(self) -> float:
        """Accumulated wire bytes (owned by the RoundDriver)."""
        return self.driver.comm

    # ------------------------------------------------------------------ data
    def _shard_key(self, cid):
        """Data shard a cid trains on. Object-grid cids own their shard
        outright; fleet cids fold onto the federated partition by
        ``cid mod n_shards`` (a 10^6-device population shares the same
        non-IID shards, many devices per shard)."""
        if self.fleet is None or cid in self.data:
            return cid
        return self._shards[int(cid) % len(self._shards)]

    def _client_hist(self, cid):
        d = self.data[self._shard_key(cid)]
        labels = d["y"] if "y" in d else d["labels"]
        return label_histogram(labels, self.ecfg.n_classes)

    def _batch_size_of(self, cid):
        """Configured batch size scaled by the joint scheduler's selected
        fraction for this round ({} / absent -> full batch). Single
        source of truth for BOTH the cost model (_p_of) and the real
        sampled batch, so priced and executed sample counts agree."""
        b = self.ecfg.batch_size
        fracs = getattr(self.scheduler, "selected_fracs", None)
        if fracs:
            f = fracs.get(cid, 1.0)
            if f != 1.0:
                b = max(1, int(round(b * f)))
        return b

    def _sample_batch(self, cid):
        d = self.data[self._shard_key(cid)]
        n = len(d["y"] if "y" in d else d["labels"])
        b = self._batch_size_of(cid)
        idx = self.rng.choice(n, size=min(b, n), replace=n < b)
        return {k: jnp.asarray(v[idx]) for k, v in d.items()}

    def _data_size(self, cid):
        d = self.data[self._shard_key(cid)]
        return float(len(d["y"] if "y" in d else d["labels"]))

    def _p_of(self, cid):
        """Samples cid actually processes per round: _sample_batch
        truncates to the client's data size, so Eq.-1 compute terms and
        the warm-up payload estimate must truncate identically or the
        time table would disagree with the metered post-warm-up times."""
        return self.ecfg.local_steps * min(self._batch_size_of(cid),
                                           int(self._data_size(cid)))

    # ------------------------------------------------- model wire legs
    def _wc_leg(self, cid, params, split, leg):
        """Route the client-portion segments through the channel's model
        leg (``leg``: 'dispatch' server->device Wc, 'collect'
        device->server updated Wc), so dispatch-codec round-trip error
        reaches training and the 2|Wc| term is metered exactly. The
        fp32 passthrough (lossless: nothing to compress or feed back)
        skips the walk entirely — the cost models then price the legs
        analytically (bit-exact seed path)."""
        if self.channel.dispatch_passthrough:
            return params
        from repro.utils.tree import get_subtree, set_subtree
        names = self.model.client_segments(split)
        paths = [p for n, p in self.model.segments() if n in names]
        subs = [get_subtree(params, p) for p in paths]
        leaves, treedef = jax.tree.flatten(subs)
        fn = (self.channel.dispatch_leaves if leg == "dispatch"
              else self.channel.collect_leaves)
        new = jax.tree.unflatten(treedef, fn(cid, leaves))
        out = params
        for p, sub in zip(paths, new):
            out = set_subtree(out, p, sub)
        return out

    def _wc_leg_cohort(self, cids, params_map, splits, leg):
        """Batched ``_wc_leg``: the whole cohort's client portions cross
        the model leg in one fused call (leaves flattened in (cid,
        leaf-index) order — the sequential transfer order, so rand-k
        draw streams and residual keys are identical)."""
        if self.channel.dispatch_passthrough:
            return {c: params_map[c] for c in cids}
        from repro.utils.tree import get_subtree, set_subtree
        pairs, meta = [], []
        for c in cids:
            names = self.model.client_segments(splits[c])
            paths = [p for n, p in self.model.segments() if n in names]
            subs = [get_subtree(params_map[c], p) for p in paths]
            leaves, treedef = jax.tree.flatten(subs)
            pairs.append((c, leaves))
            meta.append((c, paths, treedef))
        fn = (self.channel.dispatch_leaves_cohort if leg == "dispatch"
              else self.channel.collect_leaves_cohort)
        outs = fn(pairs)
        result = {}
        for (c, paths, treedef), new_leaves in zip(meta, outs):
            new = jax.tree.unflatten(treedef, new_leaves)
            out = params_map[c]
            for p, sub in zip(paths, new):
                out = set_subtree(out, p, sub)
            result[c] = out
        return result

    def _with_dispatch_report(self, report, participants):
        """Attach the metered model-leg bytes to the driver report. On
        the fp32 passthrough nothing was metered and the keys stay
        absent, so cost models fall back to the analytic 2|Wc| term —
        the exact seed pricing."""
        if self.channel.dispatch_passthrough:
            return report
        per_dir = {c: self.channel.round_dispatch_split(c)
                   for c in participants}
        report["dispatch_bytes"] = {c: per_dir[c][0] + per_dir[c][1]
                                    for c in participants}
        report["dispatch_down_bytes"] = {c: per_dir[c][0]
                                         for c in participants}
        report["dispatch_up_bytes"] = {c: per_dir[c][1]
                                       for c in participants}
        return report

    # ------------------------------------------------------- jitted pieces
    def _get_client_fwd(self, split):
        if split not in self._client_fwd:
            m = self.model
            self._client_fwd[split] = jax.jit(
                lambda p, b: m.client_forward(p, b, split))
        return self._client_fwd[split]

    def _get_server_step(self, splits):
        """splits: tuple of splits of group members (static). Returns fn
        (server_params, feats_list, batches_list) ->
        (loss, server_grads, [dfx_i])."""
        if splits not in self._server_step:
            m = self.model

            def loss_fn(sp, feats_list, batches):
                losses = []
                for s, f, b in zip(splits, feats_list, batches):
                    l, _ = m.server_loss(sp, f, b, s)
                    losses.append(l)
                # Eq. 3: loss = UNION of per-client losses -> SUM. A mean
                # halves per-client gradients vs SFL's singleton groups
                # and measurably slows S²FL (EXPERIMENTS §Accuracy).
                return jnp.sum(jnp.stack(losses))

            def step(sp, feats_list, batches):
                val, grads = jax.value_and_grad(loss_fn, argnums=(0, 1))(
                    sp, feats_list, batches)
                return val, grads[0], grads[1]

            self._server_step[splits] = jax.jit(step)
        return self._server_step[splits]

    def _get_multi_server_step(self, gsplits):
        """Batched dual of ``_get_server_step``: every concurrent group
        with the same signature (member splits + feature/batch shapes)
        rides ONE jitted call — the per-group loss/backward/SGD-update
        vmapped over a stacked (G, …) server-copy pytree, with the
        stacked copies donated so the update happens in place on
        accelerators. Returns fn (sp_stack, feats_stacks,
        batches_stacks) -> (new_sp_stack, losses (G,), dfx_stacks)."""
        key = ("multi", gsplits)
        if key not in self._server_step:
            m = self.model
            lr = self.ecfg.lr

            def loss_fn(sp, feats_list, batches):
                losses = []
                for s, f, b in zip(gsplits, feats_list, batches):
                    l, _ = m.server_loss(sp, f, b, s)
                    losses.append(l)
                return jnp.sum(jnp.stack(losses))

            def one(sp, feats_list, batches):
                val, grads = jax.value_and_grad(loss_fn, argnums=(0, 1))(
                    sp, feats_list, batches)
                # Eq.-4 Ws update folded into the same jitted program —
                # sgrads never leave the device
                new_sp = jax.tree.map(
                    lambda w, g: (w - lr * g.astype(w.dtype)
                                  ).astype(w.dtype), sp, grads[0])
                return new_sp, val, grads[1]

            self._server_step[key] = jax.jit(jax.vmap(one),
                                             donate_argnums=_donate(0))
        return self._server_step[key]

    def _get_client_update(self, split):
        """vjp through client_forward with cotangent dfx; SGD update."""
        if split not in self._client_upd:
            m = self.model
            lr = self.ecfg.lr

            def upd(p, batch, dfx):
                _, vjp = jax.vjp(lambda pp: m.client_forward(pp, batch,
                                                             split), p)
                (g,) = vjp(dfx)
                return jax.tree.map(
                    lambda w, gw: (w - lr * gw.astype(w.dtype)
                                   ).astype(w.dtype), p, g)

            self._client_upd[split] = jax.jit(upd)
        return self._client_upd[split]

    # ------------------------------------------------- fused local step
    def _local_step_fused(self, groups, splits, server_copies,
                          client_params):
        """One local step with the batched hot paths: cohort the uplink
        and downlink through ONE fused call per direction
        (``fused_comm``) and stack same-signature concurrent groups'
        server backwards into one vmapped, donated step
        (``fused_server``). Batch sampling, wire transfers and loss
        recording all happen in the sequential path's order, so RNG
        streams, rand-k draw counters, residual keys and every byte
        metered are identical to the per-device loop; delivered tensors
        match ≤1e-6 and vmapped numerics may drift ~1e-4. Returns the
        per-group losses in group order; mutates server_copies /
        client_params in place."""
        ecfg = self.ecfg
        # 1. draw batches group-major — the sequential RNG call order
        batches_by_g = [[self._sample_batch(c) for c in group]
                        for group in groups]
        fwd = {}
        for gi, group in enumerate(groups):
            for c, b in zip(group, batches_by_g[gi]):
                fwd[c] = self._get_client_fwd(splits[c])(
                    client_params[c], b)
        # 2. step 4 — the whole cohort's features cross the uplink at
        # once (one fused call; bytes metered per device, bit-equal)
        if ecfg.fused_comm:
            rx = iter(self.channel.uplink_features_cohort(
                [(c, fwd[c]) for group in groups for c in group]))
            feats_by_g = [[next(rx) for _ in group] for group in groups]
        else:
            feats_by_g = [[self.channel.uplink_features(c, fwd[c])
                           for c in group] for group in groups]
        # 3. steps 5/6 — server backwards, bucketed by signature and
        # vmapped when batching is on
        losses = [None] * len(groups)
        dfx_by_g = [None] * len(groups)

        def seq_step(gi, group):
            gsplits = tuple(splits[c] for c in group)
            loss, sgrads, dfxs = self._get_server_step(gsplits)(
                server_copies[gi], feats_by_g[gi], batches_by_g[gi])
            server_copies[gi] = jax.tree.map(
                lambda w, g: (w - ecfg.lr * g.astype(w.dtype)
                              ).astype(w.dtype), server_copies[gi],
                sgrads)
            losses[gi], dfx_by_g[gi] = float(loss), dfxs

        if ecfg.fused_server:
            buckets = {}
            for gi, group in enumerate(groups):
                payload = (feats_by_g[gi], batches_by_g[gi])
                sig = (tuple(splits[c] for c in group),
                       jax.tree.structure(payload),
                       tuple((tuple(x.shape), str(x.dtype))
                             for x in jax.tree.leaves(payload)))
                buckets.setdefault(sig, []).append(gi)
            for (gsplits, _, _), gis in buckets.items():
                if len(gis) == 1:          # nothing to batch with
                    seq_step(gis[0], groups[gis[0]])
                    continue
                new_sp, vals, dfx_stack = self._get_multi_server_step(
                    gsplits)(
                    _tree_stack([server_copies[gi] for gi in gis]),
                    _tree_stack([feats_by_g[gi] for gi in gis]),
                    _tree_stack([batches_by_g[gi] for gi in gis]))
                for j, gi in enumerate(gis):
                    server_copies[gi] = _tree_index(new_sp, j)
                    dfx_by_g[gi] = _tree_index(dfx_stack, j)
                    losses[gi] = float(vals[j])
        else:
            for gi, group in enumerate(groups):
                seq_step(gi, groups[gi])
        # 4. steps 7/8 — dfx back over the downlink (cohort flush), then
        # per-device Wc updates
        if ecfg.fused_comm:
            rx = iter(self.channel.downlink_grads_cohort(
                [(c, dfx) for gi, group in enumerate(groups)
                 for c, dfx in zip(group, dfx_by_g[gi])]))
            dfx_by_g = [[next(rx) for _ in group] for group in groups]
        else:
            dfx_by_g = [[self.channel.downlink_grads(c, dfx)
                         for c, dfx in zip(group, dfx_by_g[gi])]
                        for gi, group in enumerate(groups)]
        for gi, group in enumerate(groups):
            for c, b, dfx in zip(group, batches_by_g[gi], dfx_by_g[gi]):
                client_params[c] = self._get_client_update(splits[c])(
                    client_params[c], b, dfx)
        return losses

    # ------------------------------------------------------------- rounds
    def run_round(self):
        ecfg = self.ecfg
        if self.fleet is not None:
            # seeded fleet draw — churn/diurnal availability applied
            # inside sample_cohort, dead devices never selected
            participants = [int(c) for c in self.fleet.sample_cohort(
                self.driver.round, ecfg.clients_per_round)]
        else:
            participants = list(self.rng.choice(
                sorted(self.data), size=min(ecfg.clients_per_round,
                                            len(self.data)),
                replace=False))
        if ecfg.mode == "fedavg":
            return self._fedavg_round(participants)
        return self._sfl_round(participants)

    def _sfl_round(self, participants):
        ecfg = self.ecfg
        group_losses = []              # last local step's per-group losses

        def execute(splits):
            # the driver filters fault-killed devices from the cohort
            # before selection, so the alive list is exactly splits'
            # keys (== participants when no fault plan is armed)
            alive = [c for c in participants if c in splits]
            # Step 5: grouping (Eq. 2) — balance on, else singletons
            if not alive:
                groups = []
            elif ecfg.mode == "s2fl" and ecfg.use_balance:
                groups = greedy_groups(
                    [self._hists[self._shard_key(c)] for c in alive],
                    ecfg.group_size)
                groups = [tuple(alive[i] for i in g) for g in groups]
            else:
                groups = [(c,) for c in alive]

            server_copies = {gi: self.params for gi in range(len(groups))}

            self.channel.reset_round()
            # Steps 1/2: Wc crosses the downlink through the dispatch
            # codec (passthrough when fp32: lossless)
            if ecfg.fused_comm:
                client_params = self._wc_leg_cohort(
                    alive, {c: self.params for c in alive},
                    splits, "dispatch")
            else:
                client_params = {c: self._wc_leg(c, self.params,
                                                 splits[c], "dispatch")
                                 for c in alive}
            fused = ecfg.fused_comm or ecfg.fused_server
            for step_i in range(ecfg.local_steps):
                if fused:
                    step_losses = self._local_step_fused(
                        groups, splits, server_copies, client_params)
                    if step_i == ecfg.local_steps - 1:
                        group_losses.extend(step_losses)
                    continue
                for gi, group in enumerate(groups):
                    batches = [self._sample_batch(c) for c in group]
                    # Step 4: features cross the uplink (codec
                    # round-trip applied, exact wire bytes metered)
                    feats = [self.channel.uplink_features(
                        c, self._get_client_fwd(splits[c])(
                            client_params[c], b))
                        for c, b in zip(group, batches)]
                    gsplits = tuple(splits[c] for c in group)
                    loss, sgrads, dfxs = self._get_server_step(gsplits)(
                        server_copies[gi], feats, batches)
                    if step_i == ecfg.local_steps - 1:
                        group_losses.append(float(loss))
                    # W_s update (Eq. 4)
                    server_copies[gi] = jax.tree.map(
                        lambda w, g: (w - ecfg.lr * g.astype(w.dtype)
                                      ).astype(w.dtype),
                        server_copies[gi], sgrads)
                    # Steps 7/8: dfx back over the downlink
                    for c, b, dfx in zip(group, batches, dfxs):
                        dfx = self.channel.downlink_grads(c, dfx)
                        client_params[c] = self._get_client_update(
                            splits[c])(client_params[c], b, dfx)

            # step 8.5: the trained Wc rides back over the collect leg
            # (codec round-trip + exact metering, passthrough on fp32)
            if ecfg.fused_comm:
                client_params = self._wc_leg_cohort(
                    alive, client_params, splits, "collect")
            else:
                for c in alive:
                    client_params[c] = self._wc_leg(c, client_params[c],
                                                    splits[c], "collect")

            # hand the driver commit-granularity work items: one per
            # group, held here until its completion event lands
            keyed = {}
            for gi, group in enumerate(groups):
                gid = self._next_gid
                self._next_gid += 1
                keyed[gid] = group
                states = [ClientState(cid=c, params=client_params[c],
                                      split=splits[c],
                                      data_size=self._data_size(c),
                                      group=gid) for c in group]
                self._held[gid] = (states, server_copies[gi])
            # per-direction byte split: the pipelined timeline prices the
            # metered uplink (features) and downlink (dfx) separately
            per_dir = {c: self.channel.round_payload_split(c)
                       for c in alive}
            return self._with_dispatch_report(
                {"groups": keyed,
                 "payload_bytes": {c: self.channel.round_payload(c)
                                   for c in alive},
                 "payload_up_bytes": {c: per_dir[c][0]
                                      for c in alive},
                 "payload_down_bytes": {c: per_dir[c][1]
                                        for c in alive}},
                alive)

        rec = self.driver.run_round(participants, execute=execute)
        # a kill abandoned these work items: drop their held state (the
        # driver guarantees their commit events can never fire)
        for gid in rec.abandoned:
            self._held.pop(gid, None)
        self._commit(rec.committed)

        # Eq.-3 group losses are SUMS over members, so divide the total
        # by the (alive) participant count: a per-client mean comparable
        # across group sizes and with the FedAvg curve; nan when no
        # training happened (local_steps == 0 or no participants)
        loss = (float(np.sum(group_losses)) / max(len(rec.splits), 1)
                if group_losses else float("nan"))
        return self._record(loss, rec)

    def _fedavg_round(self, participants):
        ecfg = self.ecfg
        if self._fedavg_step is None:
            m = self.model

            def step(p, batch):
                (l, met), g = jax.value_and_grad(m.full_loss,
                                                 has_aux=True)(p, batch)
                new = jax.tree.map(
                    lambda w, gw: (w - ecfg.lr * gw.astype(w.dtype)
                                   ).astype(w.dtype), p, g)
                return new, l

            self._fedavg_step = jax.jit(step)

        losses = []

        def execute(splits):
            alive = [c for c in participants if c in splits]
            self.channel.reset_round()
            keyed = {}
            for c in alive:
                # broadcast leg: W reaches the client through the
                # dispatch codec (passthrough on fp32: lossless)
                rx = self._fedavg_broadcast(c)
                p, l = rx, None
                for _ in range(ecfg.local_steps):
                    p, l = self._fedavg_step(p, self._sample_batch(c))
                if l is not None:
                    losses.append(float(l))
                # QSGD-style collect leg: the client uploads its
                # compressed model DELTA; the server reconstructs
                # rx + decode(encode(p - rx))
                p = self._fedavg_collect(c, rx, p)
                gid = self._next_gid
                self._next_gid += 1
                keyed[gid] = (c,)
                self._held[gid] = (p, self._data_size(c))
            return self._with_dispatch_report({"groups": keyed},
                                              alive)

        rec = self.driver.run_round(participants, execute=execute)
        for gid in rec.abandoned:
            self._held.pop(gid, None)
        self._commit(rec.committed)
        # mean over participating clients (not the last client's)
        loss = float(np.mean(losses)) if losses else float("nan")
        return self._record(loss, rec)

    def _fedavg_broadcast(self, cid):
        """Server -> client full-model broadcast through the dispatch
        codec."""
        if self.channel.dispatch_passthrough:
            return self.params
        leaves, treedef = jax.tree.flatten(self.params)
        return jax.tree.unflatten(treedef,
                                  self.channel.dispatch_leaves(cid,
                                                               leaves))

    def _fedavg_collect(self, cid, base, p):
        """Client -> server QSGD-style update: compress the model delta
        against the broadcast the client actually received (error
        feedback, when on, accumulates per (device, leaf))."""
        if self.channel.dispatch_passthrough:
            return p
        lb, treedef = jax.tree.flatten(base)
        lp = jax.tree.leaves(p)
        deltas = self.channel.collect_leaves(
            cid, [a - b for a, b in zip(lp, lb)])
        return jax.tree.unflatten(
            treedef, [(b + d.astype(b.dtype)).astype(b.dtype)
                      for b, d in zip(lb, deltas)])

    def _commit(self, gids):
        """Aggregate the work items whose completion events landed in
        this window (sync: always exactly this round's; semi_async:
        possibly fewer, plus stragglers from earlier rounds)."""
        if not gids:
            return
        if self.ecfg.mode == "fedavg":
            locals_, weights = [], []
            for gid in gids:
                p, w = self._held.pop(gid)
                locals_.append(p)
                weights.append(w)
            self.params = fedavg_aggregate(locals_, weights)
            return
        states, copies = [], {}
        for gid in gids:
            st, sc = self._held.pop(gid)
            states.extend(st)
            copies[gid] = sc
        if states:                     # Step 9 + Alg. 1
            self.params = aggregate(self.model, states, copies)

    def _record(self, loss, rec):
        entry = {"round": len(self.history),
                 "clock": self.clock, "comm": self.comm,
                 "comm_up": self.channel.up_bytes,
                 "comm_down": self.channel.down_bytes,
                 # model-leg bytes actually metered (0.0 on the fp32
                 # passthrough, where the 2|Wc| term is priced
                 # analytically inside "comm")
                 "comm_dispatch": self.channel.disp_up_bytes
                 + self.channel.disp_down_bytes,
                 "loss": loss,
                 "committed": len(rec.committed),
                 "pending": rec.pending}
        if rec.phases:
            # the window's critical-path phase split (max over devices)
            entry.update(
                t_upload=max(p["up"] for p in rec.phases.values()),
                t_server=max(p["srv"] for p in rec.phases.values()),
                t_download=max(p["down"] for p in rec.phases.values()),
                downloads_in_flight=rec.downloads)
        self.history.append(entry)
        # the aggregation controller scores probes on accuracy too: the
        # observed loss trajectory disqualifies knob settings whose
        # per-round loss delta regresses past the anchor's
        kc = self.driver.knob_controller
        if kc is not None and hasattr(kc, "observe_loss"):
            kc.observe_loss(loss)
        return self.history[-1]

    def _seq_len(self):
        if self.model.is_cnn:
            return 0
        any_d = next(iter(self.data.values()))
        return any_d["tokens"].shape[1]

    # -------------------------------------------------------------- eval
    def evaluate(self, test_data, batch_size: int = 256):
        m = self.model
        n = len(test_data["y"] if "y" in test_data else test_data["labels"])
        correct, total, loss_sum = 0.0, 0, 0.0
        eval_fn = jax.jit(functools.partial(m.full_loss, train=False))
        for i in range(0, n, batch_size):
            batch = {k: jnp.asarray(v[i:i + batch_size])
                     for k, v in test_data.items()}
            l, met = eval_fn(self.params, batch)
            bsz = len(next(iter(batch.values())))
            loss_sum += float(l) * bsz
            if "acc" in met:
                correct += float(met["acc"]) * bsz
            total += bsz
        return {"loss": loss_sum / total,
                "acc": correct / total if correct else None}

    def run(self, rounds: Optional[int] = None, eval_data=None,
            eval_every: int = 10, verbose: bool = False, on_round=None):
        # rounds=0 is honored (flush-only call), only None falls back to
        # the configured count
        for r in range(self.ecfg.rounds if rounds is None else rounds):
            rec = self.run_round()
            if eval_data is not None and (r + 1) % eval_every == 0:
                rec.update(self.evaluate(eval_data))
            if verbose:
                print(rec)
            if on_round is not None:
                on_round(rec)
        # semi_async/pipeline: wait out and aggregate any still-in-flight
        # stragglers so no trained update is dropped at shutdown, and
        # fold the flush tail (late commits AND draining downloads) into
        # the final record so history[-1]['clock'] is the true total
        # wall-clock even when the flush only waited for downloads. Only
        # patch when the flush actually advanced anything: with nothing
        # pending (sync runs, or a second run()/flush) the final record
        # is already honest and must not be rewritten.
        committed, _ = self.driver.flush()
        self._commit(committed)
        if self.history:
            last = self.history[-1]
            if committed or last["pending"] \
                    or last.get("downloads_in_flight"):
                last["clock"] = self.clock
                last["committed"] += len(committed)
                last["pending"] = 0
                if "downloads_in_flight" in last:
                    last["downloads_in_flight"] = 0
        return self.history
