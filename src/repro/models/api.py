"""SplitModel — the uniform protocol the S²FL core consumes.

A model is a sequence of *units* (transformer blocks or CNN units) plus an
input stem (embedding) and an output head. A split index ``s`` places
``stem + units[:s]`` on the client and ``units[s:] + head`` on the server;
the tensor crossing the cut is the paper's intermediate feature ``fx``.

Both forward halves take the FULL parameter pytree (grads for the other
half come back as zeros) — portion sizes / upload costs are accounted by
``repro.utils.flops`` from the segment map, and Algorithm-1 aggregation
operates on segments.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import CNNConfig
from repro.models import cnn as cnn_mod
from repro.models import transformer as tf_mod
from repro.models.params import abstract_params, init_params


class SplitModel:
    def __init__(self, cfg):
        self.cfg = cfg
        self.is_cnn = isinstance(cfg, CNNConfig) or cfg.arch_type == "cnn"

    # -- parameters ---------------------------------------------------------
    def defs(self):
        return (cnn_mod.cnn_defs(self.cfg) if self.is_cnn
                else tf_mod.model_defs(self.cfg))

    def init(self, key):
        return init_params(self.defs(), key, self.cfg.param_dtype)

    def abstract(self):
        return abstract_params(self.defs(), self.cfg.param_dtype)

    # -- structure ----------------------------------------------------------
    @property
    def n_units(self) -> int:
        return (cnn_mod.cnn_n_units(self.cfg) if self.is_cnn
                else self.cfg.n_layers)

    def segments(self):
        """Ordered (name, path) segment map over the param pytree.
        Paths index into the params dict."""
        segs = []
        if self.is_cnn:
            for i in range(self.n_units):
                segs.append((f"unit:{i}", ("units", i)))
            segs.append(("head", ("head",)))
            return segs
        segs.append(("embed", ("embed",)))
        for i in range(self.cfg.n_layers):
            segs.append((f"block:{i}", ("blocks", i)))
        d = self.defs()
        if "shared_attn" in d:
            segs.append(("shared_attn", ("shared_attn",)))
        segs.append(("final_norm", ("final_norm",)))
        if "head" in d:
            segs.append(("head", ("head",)))
        return segs

    def client_segments(self, split: int):
        """Segment names trained on the client for split s."""
        names = set()
        if self.is_cnn:
            names.update(f"unit:{i}" for i in range(split))
            return names
        names.add("embed")
        names.update(f"block:{i}" for i in range(split))
        if any(self.cfg.pattern()[i][0] == "shared_attn"
               for i in range(split)):
            names.add("shared_attn")
        return names

    # -- forward halves -----------------------------------------------------
    def client_forward(self, params, batch, split: int, train: bool = True):
        """Returns features dict {'h': ..., 'aux': scalar}."""
        if self.is_cnn:
            h = cnn_mod.cnn_apply_range(self.cfg, params, batch["x"], 0,
                                        split)
            return {"h": h, "aux": jnp.zeros((), jnp.float32)}
        h = tf_mod.apply_embed(self.cfg, params, batch["tokens"],
                               batch.get("prefix"))
        positions = jnp.arange(h.shape[1], dtype=jnp.int32)
        h, _, aux = tf_mod.apply_blocks(self.cfg, params, h, 0, split,
                                        positions, train=train)
        return {"h": h, "aux": aux}

    def server_loss(self, params, feats, batch, split: int,
                    train: bool = True):
        """CE(+aux) from the cut to the loss. Returns (loss, metrics)."""
        if self.is_cnn:
            h = cnn_mod.cnn_apply_range(self.cfg, params, feats["h"], split,
                                        self.n_units)
            logits = cnn_mod.cnn_head(self.cfg, params, h)
            onehot = jax.nn.one_hot(batch["y"], self.cfg.n_classes)
            ce = -jnp.mean(jnp.sum(onehot * jax.nn.log_softmax(logits), -1))
            acc = jnp.mean((jnp.argmax(logits, -1) == batch["y"])
                           .astype(jnp.float32))
            return ce + feats["aux"], {"ce": ce, "acc": acc}
        h = feats["h"]
        positions = jnp.arange(h.shape[1], dtype=jnp.int32)
        h, _, aux = tf_mod.apply_blocks(self.cfg, params, h, split,
                                        self.cfg.n_layers, positions,
                                        train=train)
        logits = tf_mod.apply_head(self.cfg, params, h)
        P = logits.shape[1] - batch["tokens"].shape[1]
        if P:
            logits = logits[:, P:]
        from repro.models.layers import cross_entropy
        ce = cross_entropy(logits, batch["labels"], self.cfg.vocab_size)
        loss = ce + aux + feats["aux"]
        return loss, {"ce": ce, "aux": aux + feats["aux"]}

    def full_loss(self, params, batch, train: bool = True):
        """Monolithic loss (FedAvg baseline / sanity oracle)."""
        if self.is_cnn:
            return cnn_mod.cnn_loss(self.cfg, params, batch)
        return tf_mod.lm_loss(self.cfg, params, batch, train=train)

    # -- inference (LM only) -------------------------------------------------
    def prefill(self, params, tokens, max_len, prefix=None):
        return tf_mod.prefill(self.cfg, params, tokens, max_len, prefix)

    def decode_step(self, params, token, caches, index):
        return tf_mod.decode_step(self.cfg, params, token, caches, index)


def get_subtree(params, path):
    node = params
    for p in path:
        node = node[p]
    return node
