"""Attention: GQA (full / sliding-window) and MLA (DeepSeek latent), with
train / prefill / decode paths and KV caches.

Memory strategy (TPU-adapted): for long sequences the XLA path uses a
blockwise q-chunk scan (flash-attention schedule expressed in lax.scan with
per-chunk remat) so scores never materialize at (S, S). The Pallas kernel
in ``repro.kernels.flash_attention`` implements the same schedule with
explicit VMEM BlockSpecs for the TPU target; ``cfg.attn_impl`` selects.

Cache layouts (batch-first, sequence second so long-context caches can be
sequence-sharded over the `data` mesh axis):
  full attn : {'k': (B, S, K, D), 'v': (B, S, K, D)}
  swa       : ring buffer {'k': (B, W, K, D), 'v': ..., 'slot_pos': (W,)}
  mla       : {'latent': (B, S, R), 'k_rope': (B, S, Dr)}
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope
from repro.models.params import ParamDef

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# defs
# ---------------------------------------------------------------------------
def attn_defs(cfg):
    d, H = cfg.d_model, cfg.n_heads
    if cfg.mla:
        R, Dr, Dn, Dv = (cfg.kv_lora_rank, cfg.qk_rope_head_dim,
                         cfg.qk_nope_head_dim, cfg.v_head_dim)
        return {
            "wq": ParamDef((d, H, Dn + Dr), ("embed", "heads", "none")),
            "w_dkv": ParamDef((d, R), ("embed", "lora")),
            "w_kr": ParamDef((d, Dr), ("embed", "none")),
            "latent_norm": ParamDef((R,), ("lora",), init="ones"),
            "w_uk": ParamDef((R, H, Dn), ("lora", "heads", "none")),
            "w_uv": ParamDef((R, H, Dv), ("lora", "heads", "none")),
            "wo": ParamDef((H, Dv, d), ("heads", "none", "embed")),
        }
    K, D = cfg.n_kv_heads, cfg.head_dim
    return {
        "wq": ParamDef((d, H, D), ("embed", "heads", "head_dim")),
        "wk": ParamDef((d, K, D), ("embed", "kv_heads", "head_dim")),
        "wv": ParamDef((d, K, D), ("embed", "kv_heads", "head_dim")),
        "wo": ParamDef((H, D, d), ("heads", "head_dim", "embed")),
    }


# ---------------------------------------------------------------------------
# core attention math (grouped, blockwise)
# ---------------------------------------------------------------------------
def _pick_q_block(S: int) -> int:
    for b in (1024, 512, 256, 128):
        if S % b == 0 and S > b:
            return b
    return S


def grouped_attention(q, k, v, q_pos, k_pos, *, window: int = 0,
                      causal: bool = True, impl: str = "xla"):
    """q: (B,S,H,Dq) k: (B,T,K,Dq) v: (B,T,K,Dv); GQA via H = K*G.

    Returns (B,S,H,Dv). Positions are 1-D int32 arrays (right-aligned,
    no padding semantics — masking is purely positional).
    """
    B, S, H, Dq = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(Dq)

    if impl == "pallas" and S > 1:
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(q, k, v, q_pos, k_pos, window=window,
                                      causal=causal)

    qg = q.reshape(B, S, K, G, Dq)

    def block(q_blk, qp_blk):
        s = jnp.einsum("bskgd,btkd->bkgst", q_blk.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
        mask = jnp.ones((q_blk.shape[1], T), bool)
        if causal:
            mask &= qp_blk[:, None] >= k_pos[None, :]
        if window:
            mask &= qp_blk[:, None] - k_pos[None, :] < window
        mask &= k_pos[None, :] >= 0
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgst,btkd->bskgd", w.astype(v.dtype), v)
        return o.reshape(B, q_blk.shape[1], H, v.shape[-1])

    qb = _pick_q_block(S)
    if qb == S:
        return block(qg, q_pos)

    n = S // qb
    qg_c = qg.reshape(B, n, qb, K, G, Dq)
    qp_c = q_pos.reshape(n, qb)

    def body(_, inp):
        qi, qpi = inp
        return None, jax.checkpoint(block)(qi, qpi)

    _, out = jax.lax.scan(body, None,
                          (jnp.moveaxis(qg_c, 1, 0), qp_c))
    return jnp.moveaxis(out, 0, 1).reshape(B, S, H, v.shape[-1])


# ---------------------------------------------------------------------------
# GQA paths
# ---------------------------------------------------------------------------
def init_attn_cache(cfg, kind: str, batch: int, max_len: int, dtype):
    if cfg.mla:
        return {
            "latent": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
        }
    K, D = cfg.n_kv_heads, cfg.head_dim
    L = min(max_len, cfg.sliding_window) if kind == "swa" else max_len
    cache = {"k": jnp.zeros((batch, L, K, D), dtype),
             "v": jnp.zeros((batch, L, K, D), dtype)}
    if kind == "swa":
        cache["slot_pos"] = jnp.full((L,), -1, jnp.int32)
    return cache


def gqa_apply(cfg, kind, p, x, positions, cache=None, cache_index=None):
    """x: (B,S,d). Train: cache None. Prefill: cache dict is filled and
    returned. Decode: S==1, cache_index = current position (scalar)."""
    B, S, d = x.shape
    window = cfg.sliding_window if kind == "swa" else 0
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if cache is None:                                   # train
        out = grouped_attention(q, k, v, positions, positions,
                                window=window, causal=True,
                                impl=cfg.attn_impl)
    elif S > 1:                                         # prefill
        if window and cache["k"].shape[1] < S:          # fill ring buffer
            # keep the last W positions, laid out so slot == pos % W (the
            # invariant decode appends rely on)
            W = cache["k"].shape[1]
            slots = positions[S - W:] % W
            order = jnp.argsort(slots)
            cache = {"k": k[:, S - W:][:, order], "v": v[:, S - W:][:, order],
                     "slot_pos": positions[S - W:][order]}
        else:
            L = cache["k"].shape[1]
            cache = dict(cache)
            cache["k"] = jax.lax.dynamic_update_slice(
                cache["k"], k, (0, 0, 0, 0))
            cache["v"] = jax.lax.dynamic_update_slice(
                cache["v"], v, (0, 0, 0, 0))
            if "slot_pos" in cache:
                pos_pad = (jnp.pad(positions, (0, L - S), constant_values=-1)
                           if L > S else positions[:L])
                cache["slot_pos"] = pos_pad
        out = grouped_attention(q, k, v, positions, positions,
                                window=window, causal=True,
                                impl=cfg.attn_impl)
    else:                                               # decode, S == 1
        idx = cache_index
        cache = dict(cache)
        if window:
            W = cache["k"].shape[1]
            slot = idx % W
            cache["k"] = jax.lax.dynamic_update_slice(
                cache["k"], k, (0, slot, 0, 0))
            cache["v"] = jax.lax.dynamic_update_slice(
                cache["v"], v, (0, slot, 0, 0))
            cache["slot_pos"] = jax.lax.dynamic_update_slice(
                cache["slot_pos"], idx[None].astype(jnp.int32), (slot,))
            k_pos = cache["slot_pos"]
        else:
            cache["k"] = jax.lax.dynamic_update_slice(
                cache["k"], k, (0, idx, 0, 0))
            cache["v"] = jax.lax.dynamic_update_slice(
                cache["v"], v, (0, idx, 0, 0))
            T = cache["k"].shape[1]
            k_pos = jnp.where(jnp.arange(T) <= idx, jnp.arange(T), -1)
        out = grouped_attention(q, cache["k"], cache["v"], positions, k_pos,
                                window=window, causal=not window, impl="xla")

    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, cache


# ---------------------------------------------------------------------------
# MLA paths
# ---------------------------------------------------------------------------
def _mla_latent(cfg, p, x, positions):
    from repro.models.layers import rmsnorm
    latent = x @ p["w_dkv"].astype(x.dtype)
    latent = rmsnorm({"scale": p["latent_norm"]}, latent, cfg.norm_eps)
    k_rope = x @ p["w_kr"].astype(x.dtype)               # (B,S,Dr)
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]
    return latent, k_rope


def mla_apply(cfg, p, x, positions, cache=None, cache_index=None):
    B, S, d = x.shape
    H = cfg.n_heads
    Dn, Dr, Dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    q_nope, q_rope = q[..., :Dn], q[..., Dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    if cache is None or S > 1:                          # train / prefill
        latent, k_rope = _mla_latent(cfg, p, x, positions)
        k_nope = jnp.einsum("btr,rhk->bthk", latent, p["w_uk"].astype(x.dtype))
        v = jnp.einsum("btr,rhk->bthk", latent, p["w_uv"].astype(x.dtype))
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (B, S, H, Dr))], axis=-1)
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = grouped_attention(qq, k, v, positions, positions,
                                causal=True, impl=cfg.attn_impl)
        if cache is not None:
            cache = {
                "latent": jax.lax.dynamic_update_slice(
                    cache["latent"], latent, (0, 0, 0)),
                "k_rope": jax.lax.dynamic_update_slice(
                    cache["k_rope"], k_rope, (0, 0, 0)),
            }
    else:                                               # decode (absorbed)
        idx = cache_index
        latent, k_rope = _mla_latent(cfg, p, x, positions)
        cache = {
            "latent": jax.lax.dynamic_update_slice(
                cache["latent"], latent, (0, idx, 0)),
            "k_rope": jax.lax.dynamic_update_slice(
                cache["k_rope"], k_rope, (0, idx, 0)),
        }
        T = cache["latent"].shape[1]
        scale = 1.0 / math.sqrt(Dn + Dr)
        # absorb w_uk into the query: (B,1,H,R)
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"].astype(x.dtype))
        s = (jnp.einsum("bshr,btr->bhst", q_lat.astype(jnp.float32),
                        cache["latent"].astype(jnp.float32))
             + jnp.einsum("bshk,btk->bhst", q_rope.astype(jnp.float32),
                          cache["k_rope"].astype(jnp.float32))) * scale
        valid = jnp.arange(T) <= idx
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhst,btr->bshr", w.astype(x.dtype),
                           cache["latent"])
        out = jnp.einsum("bshr,rhk->bshk", o_lat, p["w_uv"].astype(x.dtype))

    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, cache


def attn_apply(cfg, kind, p, x, positions, cache=None, cache_index=None):
    if cfg.mla:
        return mla_apply(cfg, p, x, positions, cache, cache_index)
    return gqa_apply(cfg, kind, p, x, positions, cache, cache_index)
