"""Basic layers: RMSNorm, MLPs, embeddings, rotary embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def rmsnorm_defs(d_model: int):
    return {"scale": ParamDef((d_model,), ("embed",), init="ones")}


def rmsnorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Gated MLP (swiglu / geglu)
# ---------------------------------------------------------------------------
def mlp_defs(d_model: int, d_ff: int, ff_axis: str = "ff"):
    return {
        "w_gate": ParamDef((d_model, d_ff), ("embed", ff_axis)),
        "w_up": ParamDef((d_model, d_ff), ("embed", ff_axis)),
        "w_down": ParamDef((d_ff, d_model), (ff_axis, "embed")),
    }


def mlp(params, x, act: str = "silu"):
    actf = jax.nn.silu if act == "silu" else jax.nn.gelu
    g = actf(x @ params["w_gate"].astype(x.dtype))
    u = x @ params["w_up"].astype(x.dtype)
    return (g * u) @ params["w_down"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------
def embed_defs(vocab_padded: int, d_model: int):
    return {"tok": ParamDef((vocab_padded, d_model), ("vocab", "embed"),
                            init="normal")}


def embed(params, tokens, compute_dtype):
    return params["tok"].astype(compute_dtype)[tokens]


def unembed(params, h, *, tied: bool, head_params=None):
    w = params["tok"] if tied else head_params["w"]
    if tied:
        return h @ w.astype(h.dtype).T
    return h @ w.astype(h.dtype)


def head_defs(d_model: int, vocab_padded: int):
    return {"w": ParamDef((d_model, vocab_padded), ("embed", "vocab"))}


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D) with positions (..., S) or (S,)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (d/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs   # (..., S, d/2)
    # broadcast over head axis: (..., S, 1, d/2)
    ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def cross_entropy(logits, labels, vocab_size: int, *, mask=None):
    """Mean next-token CE in f32; labels == -100 or mask==0 are ignored.

    logits may be vocab-padded: positions >= vocab_size are masked out.
    """
    logits = logits.astype(jnp.float32)
    if logits.shape[-1] > vocab_size:
        pad = logits.shape[-1] - vocab_size
        neg = jnp.full((pad,), -1e9, logits.dtype)
        logits = logits.at[..., vocab_size:].set(neg)
    valid = (labels >= 0)
    if mask is not None:
        valid = valid & (mask > 0)
    labels_safe = jnp.clip(labels, 0, vocab_size - 1)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels_safe[..., None], axis=-1)[..., 0]
    nll = (logz - ll) * valid
    return nll.sum() / jnp.maximum(valid.sum(), 1)
