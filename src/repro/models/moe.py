"""Mixture-of-Experts FFN: top-k routing, shared experts, capacity-based
scatter dispatch (static shapes, SPMD-friendly — the expert axis shards over
the `model` mesh axis, so the dispatch/combine gathers lower to all-to-all
style collectives).

Dispatch avoids the classic (T, E, C) one-hot (infeasible at pod batch
sizes). Positions-in-expert come from SORT-BASED ranking: a stable argsort
of the (T*k,) expert assignments, ranks within runs via searchsorted, then
inverse-permute. The earlier (T*k, E) one-hot + cumsum formulation costs
1.7e15 flops/chip in compiled HLO at kimi-k2 train shapes (XLA's cumsum
lowering), vs 3.5e8 for the sort — see EXPERIMENTS.md §Perf-moe-dispatch.
Dispatch/combine are scatter/gather at (expert, slot); the compute-bound
expert matmul can route through the ``repro.kernels.moe_gmm`` Pallas
kernel (``cfg.attn_impl == 'pallas'``).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef


def moe_defs(cfg):
    d, E, F = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    defs = {
        "router": ParamDef((d, E), ("embed", "experts"), dtype="float32"),
        "w_gate": ParamDef((E, d, F), ("experts", "embed", "expert_ff")),
        "w_up": ParamDef((E, d, F), ("experts", "embed", "expert_ff")),
        "w_down": ParamDef((E, F, d), ("experts", "expert_ff", "embed")),
    }
    if cfg.n_shared_experts:
        Fs = cfg.moe_d_ff * cfg.n_shared_experts
        defs["shared"] = {
            "w_gate": ParamDef((d, Fs), ("embed", "ff")),
            "w_up": ParamDef((d, Fs), ("embed", "ff")),
            "w_down": ParamDef((Fs, d), ("ff", "embed")),
        }
    return defs


def _expert_ffn(p, x, act):
    """x: (E, C, d) -> (E, C, d), batched over experts."""
    actf = jax.nn.silu if act == "silu" else jax.nn.gelu
    g = actf(jnp.einsum("ecd,edf->ecf", x, p["w_gate"].astype(x.dtype)))
    u = jnp.einsum("ecd,edf->ecf", x, p["w_up"].astype(x.dtype))
    return jnp.einsum("ecf,efd->ecd", g * u, p["w_down"].astype(x.dtype))


def _dispatch_combine(cfg, p, xt, *, capacity_factor: float):
    """Dispatch -> expert FFN -> combine for a token slab xt (T, d).
    Positions are first-come-first-served in token order (sort-based)."""
    T, d = xt.shape
    E, k = cfg.n_experts, cfg.top_k

    logits = xt.astype(jnp.float32) @ p["router"]        # (T, E) f32
    gates = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(gates, k)                 # (T, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style): E * sum_e f_e * P_e
    density = jnp.zeros((E,), jnp.float32).at[topi.reshape(-1)].add(
        1.0) / (T * k)
    aux = E * jnp.sum(density * gates.mean(0)) * cfg.router_aux_coef

    C = int(capacity_factor * k * T / E)
    C = max(8, math.ceil(C / 8) * 8)

    flat_e = topi.reshape(-1)                            # (T*k,)
    N = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E))   # run starts
    rank_sorted = jnp.arange(N) - starts[sorted_e]
    flat_pos = jnp.zeros((N,), jnp.int32).at[order].set(rank_sorted)
    keep = flat_pos < C                                  # overflow dropped

    safe_pos = jnp.where(keep, flat_pos, C - 1)
    x_rep = jnp.repeat(xt, k, axis=0)                    # (T*k, d)
    exp_in = jnp.zeros((E, C, d), xt.dtype).at[flat_e, safe_pos].add(
        jnp.where(keep[:, None], x_rep, 0).astype(xt.dtype))

    if cfg.attn_impl == "pallas":
        from repro.kernels.moe_gmm import ops as gmm_ops
        exp_out = gmm_ops.expert_ffn(p, exp_in, cfg.act)
    else:
        exp_out = _expert_ffn(p, exp_in, cfg.act)

    gathered = exp_out[flat_e, safe_pos]                 # (T*k, d)
    gathered = jnp.where(keep[:, None], gathered, 0)
    w = topw.reshape(-1).astype(xt.dtype)
    out = (gathered * w[:, None]).reshape(T, k, d).sum(axis=1)
    return out, aux


def moe_apply(cfg, p, x, *, capacity_factor: float = 1.25):
    """x: (B,S,d). Returns (out, aux_loss).

    When ``cfg.moe_dispatch_shards > 1`` (set by the pod-scale launchers),
    tokens are bucketed PER DATA SHARD: the batch is viewed as
    (shards, T/shards, d) — physically sharded over `data` — and ranking/
    scatter/gather are vmapped over the shard dim, so the capacity buffer
    is (shards, E, C/shards, d) with every scatter local to its shard and
    only the expert matmul crossing the expert-parallel axis. The global
    single-bucket form replicates the (E, C, d) buffer across the data
    axis and all-reduces it per layer — measured 2.2e11 collective
    B/chip/layer at kimi-k2 train shapes (EXPERIMENTS.md §Perf-kimi).
    Capacity becomes per-shard (drop decisions local), matching practical
    expert-parallel systems.
    """
    B, S, d = x.shape
    T = B * S
    shards = getattr(cfg, "moe_dispatch_shards", 0) or 1
    if shards > 1 and B % shards == 0:
        from jax.sharding import PartitionSpec as P
        axes = getattr(cfg, "moe_dispatch_axes", ()) or None
        cst = (lambda v, s: jax.lax.with_sharding_constraint(v, s)) \
            if axes else (lambda v, s: v)
        xs = x.reshape(shards, T // shards, d)
        xs = cst(xs, P(axes, None, None))
        out, aux = jax.vmap(
            lambda xt: _dispatch_combine(cfg, p, xt,
                                         capacity_factor=capacity_factor)
        )(xs)
        out = cst(out, P(axes, None, None)).reshape(T, d)
        aux = aux.mean()
    else:
        out, aux = _dispatch_combine(cfg, p, x.reshape(T, d),
                                     capacity_factor=capacity_factor)

    if cfg.n_shared_experts:
        from repro.models.layers import mlp
        out = out + mlp(p["shared"], x.reshape(T, d), cfg.act)
    return out.reshape(B, S, d), aux
