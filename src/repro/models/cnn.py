"""The paper's CNN families (ResNet8 / VGG16 / MobileNet, CIFAR-scale),
as sequential unit stacks so the S²FL sliding split applies at unit
granularity (the paper's three split layers are unit indices).

BatchNorm is the stateless, batch-statistics form (standard in FL
reproductions — running stats don't aggregate across clients; noted in
DESIGN/EXPERIMENTS).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef, abstract_params, init_params

_NONE4 = ("none",) * 4


def _conv_defs(k, cin, cout, name="w"):
    return {name: ParamDef((k, k, cin, cout), _NONE4, init="conv")}


def _bn_defs(c):
    return {"scale": ParamDef((c,), ("none",), init="ones"),
            "bias": ParamDef((c,), ("none",), init="zeros")}


def _conv(p, x, stride=1, groups=1, name="w"):
    return jax.lax.conv_general_dilated(
        x, p[name].astype(x.dtype), (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)


def _bn(p, x, eps=1e-5):
    mean = x.mean(axis=(0, 1, 2), keepdims=True)
    var = x.var(axis=(0, 1, 2), keepdims=True)
    xn = (x - mean) * jax.lax.rsqrt(var + eps)
    return xn * p["scale"] + p["bias"]


def _maxpool(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                 (1, 2, 2, 1), "VALID")


# ---------------------------------------------------------------------------
# unit builders per family: each unit -> (defs, apply_fn, out_shape_fn)
# ---------------------------------------------------------------------------
def _resnet_units(cfg):
    units = []
    c_in = cfg.in_channels

    def stem_defs(c_in=c_in):
        return {"conv": _conv_defs(3, c_in, 16), "bn": _bn_defs(16)}

    def stem_apply(p, x):
        return jax.nn.relu(_bn(p["bn"], _conv(p["conv"], x)))

    units.append((stem_defs(), stem_apply))
    c_prev = 16
    for c, n_blocks, stride in cfg.stages:
        for b in range(n_blocks):
            s = stride if b == 0 else 1
            proj = (s != 1) or (c_prev != c)

            def blk_defs(c_prev=c_prev, c=c, proj=proj):
                d = {"conv1": _conv_defs(3, c_prev, c), "bn1": _bn_defs(c),
                     "conv2": _conv_defs(3, c, c), "bn2": _bn_defs(c)}
                if proj:
                    d["proj"] = _conv_defs(1, c_prev, c)
                return d

            def blk_apply(p, x, s=s, proj=proj):
                h = jax.nn.relu(_bn(p["bn1"], _conv(p["conv1"], x, s)))
                h = _bn(p["bn2"], _conv(p["conv2"], h))
                skip = _conv(p["proj"], x, s) if proj else x
                return jax.nn.relu(h + skip)

            units.append((blk_defs(), blk_apply))
            c_prev = c
    return units, c_prev


def _vgg_units(cfg):
    units = []
    c_prev = cfg.in_channels
    for si, (c, n_convs) in enumerate(cfg.stages):
        for ci in range(n_convs):
            last = ci == n_convs - 1

            def u_defs(c_prev=c_prev, c=c):
                return {"conv": _conv_defs(3, c_prev, c), "bn": _bn_defs(c)}

            def u_apply(p, x, last=last):
                h = jax.nn.relu(_bn(p["bn"], _conv(p["conv"], x)))
                return _maxpool(h) if last else h

            units.append((u_defs(), u_apply))
            c_prev = c
    return units, c_prev


def _mobilenet_units(cfg):
    units = []

    def stem_defs():
        return {"conv": _conv_defs(3, cfg.in_channels, 32),
                "bn": _bn_defs(32)}

    def stem_apply(p, x):
        return jax.nn.relu(_bn(p["bn"], _conv(p["conv"], x, 1)))

    units.append((stem_defs(), stem_apply))
    c_prev = 32
    for c, stride in cfg.stages:
        def u_defs(c_prev=c_prev, c=c):
            return {"dw": _conv_defs(3, 1, c_prev, "w"),
                    "bn1": _bn_defs(c_prev),
                    "pw": _conv_defs(1, c_prev, c), "bn2": _bn_defs(c)}

        def u_apply(p, x, stride=stride, c_prev=c_prev):
            h = jax.lax.conv_general_dilated(
                x, p["dw"]["w"].astype(x.dtype), (stride, stride), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
                feature_group_count=c_prev)
            h = jax.nn.relu(_bn(p["bn1"], h))
            h = jax.nn.relu(_bn(p["bn2"], _conv(p["pw"], h)))
            return h

        units.append((u_defs(), u_apply))
        c_prev = c
    return units, c_prev


_BUILDERS = {"resnet": _resnet_units, "vgg": _vgg_units,
             "mobilenet": _mobilenet_units}


def cnn_units(cfg):
    return _BUILDERS[cfg.family](cfg)


def cnn_defs(cfg):
    units, c_final = cnn_units(cfg)
    return {
        "units": [d for d, _ in units],
        "head": {"w": ParamDef((c_final, cfg.n_classes), ("none", "none")),
                 "b": ParamDef((cfg.n_classes,), ("none",), init="zeros")},
    }


def init_cnn(cfg, key):
    return init_params(cnn_defs(cfg), key, cfg.param_dtype)


def abstract_cnn(cfg):
    return abstract_params(cnn_defs(cfg), cfg.param_dtype)


def cnn_apply_range(cfg, params, x, lo: int, hi: int):
    units, _ = cnn_units(cfg)
    for i in range(lo, hi):
        x = units[i][1](params["units"][i], x)
    return x


def cnn_head(cfg, params, x):
    x = x.mean(axis=(1, 2))                               # global avg pool
    return x @ params["head"]["w"] + params["head"]["b"]


def cnn_n_units(cfg):
    return len(_BUILDERS[cfg.family](cfg)[0])


def cnn_loss(cfg, params, batch):
    """batch: {'x': (B,H,W,C), 'y': (B,)}"""
    h = cnn_apply_range(cfg, params, batch["x"], 0, cnn_n_units(cfg))
    logits = cnn_head(cfg, params, h)
    onehot = jax.nn.one_hot(batch["y"], cfg.n_classes)
    ce = -jnp.mean(jnp.sum(onehot * jax.nn.log_softmax(logits), axis=-1))
    acc = jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))
    return ce, {"ce": ce, "acc": acc}
