"""Mamba2 / SSD (state-space duality) block [arXiv:2405.21060].

Chunked dual form for train/prefill (O(S * chunk) memory, matmul-friendly
for the MXU) and O(1)-state recurrent decode. The pure-jnp chunked scan here
is the oracle for the ``repro.kernels.ssd_scan`` Pallas kernel;
``cfg.attn_impl == 'pallas'`` routes the core scan through the kernel.

Single-group SSD: in_proj split into separate z / x / B / C / dt projections
(separate params so the d_inner axes shard cleanly over the `model` mesh
axis — see DESIGN.md §6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef


def ssm_defs(cfg):
    d, di, N, Hs = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    ck = cfg.ssm_conv
    return {
        "wz": ParamDef((d, di), ("embed", "ssm_inner")),
        "wx": ParamDef((d, di), ("embed", "ssm_inner")),
        "wB": ParamDef((d, N), ("embed", "ssm_state")),
        "wC": ParamDef((d, N), ("embed", "ssm_state")),
        "wdt": ParamDef((d, Hs), ("embed", "ssm_heads")),
        "conv_x": ParamDef((ck, di), ("conv_k", "ssm_inner"), init="normal",
                           scale=0.5),
        "conv_B": ParamDef((ck, N), ("conv_k", "ssm_state"), init="normal",
                           scale=0.5),
        "conv_C": ParamDef((ck, N), ("conv_k", "ssm_state"), init="normal",
                           scale=0.5),
        "A_log": ParamDef((Hs,), ("ssm_heads",), init="ssm_a", dtype="float32"),
        "D": ParamDef((Hs,), ("ssm_heads",), init="ones", dtype="float32"),
        "dt_bias": ParamDef((Hs,), ("ssm_heads",), init="ssm_dt",
                            dtype="float32"),
        "norm": ParamDef((di,), ("ssm_inner",), init="ones"),
        "wo": ParamDef((di, d), ("ssm_inner", "embed")),
    }


# ---------------------------------------------------------------------------
# causal depthwise conv
# ---------------------------------------------------------------------------
def _causal_conv(x, w, conv_state=None):
    """x: (B,S,C), w: (k,C) depthwise causal conv. conv_state (B,k-1,C) is
    the tail of the previous segment (decode); returns (y, new_state)."""
    k = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)              # (B, S+k-1, C)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else pad
    return y, new_state


# ---------------------------------------------------------------------------
# SSD chunked scan (pure jnp oracle)
# ---------------------------------------------------------------------------
def _segsum(x):
    """x: (..., L). Returns (..., L, L): sum_{j<i<=k} x_i lower-triangular
    cumulative segment sums with -inf above diagonal."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    # out[k, j] = sum_{j < i <= k} x_i = cs[k] - cs[j]
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_scan_ref(x, dt, A, B, C, chunk: int, initial_state=None):
    """SSD chunked dual form.

    x:  (b, s, h, p)  inputs per head
    dt: (b, s, h)     softplus-ed step sizes (>0)
    A:  (h,)          negative decay rates
    B:  (b, s, n)     input projection (single group)
    C:  (b, s, n)     output projection
    Returns (y (b,s,h,p), final_state (b,h,p,n)).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    c, l = s // chunk, chunk
    xc = x.reshape(b, c, l, h, p)
    dtc = dt.reshape(b, c, l, h)
    Bc = B.reshape(b, c, l, n)
    Cc = C.reshape(b, c, l, n)

    dA = dtc * A[None, None, None]                       # (b,c,l,h) <= 0
    dA_cs = jnp.cumsum(dA, axis=2)                       # within-chunk cumsum

    # 1) intra-chunk (quadratic within chunk)
    L = jnp.exp(_segsum(jnp.moveaxis(dA, 2, -1)))        # (b,c,h,l,l)
    scores = jnp.einsum("bcln,bcmn->bclm", Cc, Bc)       # (b,c,l,l)
    W = L * scores[:, :, None, :, :]                     # (b,c,h,l,m)
    y_diag = jnp.einsum("bchlm,bcmh,bcmhp->bclhp", W.astype(x.dtype),
                        dtc.astype(x.dtype), xc)

    # 2) chunk states: state_c = sum_m exp(sum_{i>m} dA_i) * dt_m B_m x_m
    decay_tail = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)    # (b,c,l,h)
    states = jnp.einsum("bclh,bcln,bclhp->bchpn",
                        (decay_tail * dtc).astype(x.dtype), Bc, xc)

    # 3) inter-chunk recurrence over c
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])            # (b,c,h)
    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), x.dtype)

    def step(carry, inp):
        st, dec = inp                                    # (b,h,p,n), (b,h)
        new = carry * dec[..., None, None].astype(x.dtype) + st
        return new, carry                                # emit PREVIOUS state

    final, prev_states = jax.lax.scan(
        step, initial_state,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)        # (b,c,h,p,n)

    # 4) inter-chunk output: y_off = C_l . (exp(dA_cs_l) * prev_state)
    in_decay = jnp.exp(dA_cs)                            # (b,c,l,h)
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp", Cc, prev_states,
                       in_decay.astype(x.dtype))

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final


def ssd_decode_step(x, dt, A, B, C, state):
    """One-token recurrence. x: (b,1,h,p), dt: (b,1,h), B/C: (b,1,n),
    state: (b,h,p,n). y = C . state' + (handled by caller: D skip)."""
    dA = jnp.exp(dt[:, 0] * A[None])                     # (b,h)
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0].astype(x.dtype), B[:, 0],
                     x[:, 0])
    state = state * dA[..., None, None].astype(x.dtype) + upd
    y = jnp.einsum("bn,bhpn->bhp", C[:, 0], state)[:, None]
    return y, state


# ---------------------------------------------------------------------------
# full block
# ---------------------------------------------------------------------------
def init_ssm_cache(cfg, batch: int, dtype):
    di, N, Hs, ck = (cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads,
                     cfg.ssm_conv)
    return {
        "state": jnp.zeros((batch, Hs, cfg.ssm_head_dim, N), dtype),
        "conv_x": jnp.zeros((batch, ck - 1, di), dtype),
        "conv_B": jnp.zeros((batch, ck - 1, N), dtype),
        "conv_C": jnp.zeros((batch, ck - 1, N), dtype),
    }


def ssm_apply(cfg, p, x_in, cache=None):
    """Mamba2 block. x_in: (B,S,d). Returns (out, new_cache)."""
    from repro.models.layers import rmsnorm
    B_, S, d = x_in.shape
    Hs, P_, N = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state

    z = x_in @ p["wz"].astype(x_in.dtype)
    x = x_in @ p["wx"].astype(x_in.dtype)
    Bp = x_in @ p["wB"].astype(x_in.dtype)
    Cp = x_in @ p["wC"].astype(x_in.dtype)
    dt_raw = x_in @ p["wdt"].astype(x_in.dtype)

    cs_x = cache["conv_x"] if cache else None
    cs_B = cache["conv_B"] if cache else None
    cs_C = cache["conv_C"] if cache else None
    x, ns_x = _causal_conv(x, p["conv_x"].astype(x.dtype), cs_x)
    Bp, ns_B = _causal_conv(Bp, p["conv_B"].astype(x.dtype), cs_B)
    Cp, ns_C = _causal_conv(Cp, p["conv_C"].astype(x.dtype), cs_C)
    x, Bp, Cp = jax.nn.silu(x), jax.nn.silu(Bp), jax.nn.silu(Cp)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None])     # (B,S,Hs) f32
    A = -jnp.exp(p["A_log"])                             # (Hs,) negative
    xh = x.reshape(B_, S, Hs, P_)

    if cache is None or S > 1:
        if S % cfg.ssm_chunk:
            pad = cfg.ssm_chunk - S % cfg.ssm_chunk
            xh_p = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            B_p = jnp.pad(Bp, ((0, 0), (0, pad), (0, 0)))
            C_p = jnp.pad(Cp, ((0, 0), (0, pad), (0, 0)))
        else:
            xh_p, dt_p, B_p, C_p = xh, dt, Bp, Cp
        init = cache["state"] if cache else None
        if cfg.attn_impl == "pallas":
            from repro.kernels.ssd_scan import ops as ssd_ops
            y, state = ssd_ops.ssd_scan(xh_p, dt_p, A, B_p, C_p,
                                        chunk=cfg.ssm_chunk,
                                        initial_state=init)
        else:
            y, state = ssd_scan_ref(xh_p, dt_p, A, B_p, C_p,
                                    chunk=cfg.ssm_chunk, initial_state=init)
        y = y[:, :S]
    else:
        y, state = ssd_decode_step(xh, dt, A, Bp, Cp, cache["state"])

    y = y + xh * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B_, S, cfg.d_inner)
    y = rmsnorm({"scale": p["norm"]}, y, cfg.norm_eps) * jax.nn.silu(z)
    out = y @ p["wo"].astype(y.dtype)

    new_cache = None
    if cache is not None:
        new_cache = {"state": state, "conv_x": ns_x, "conv_B": ns_B,
                     "conv_C": ns_C}
    return out, new_cache
