"""Logical-axis -> mesh-axis sharding rules (MaxText-style).

One `model` (tensor/expert-parallel) axis, one `data` axis (cohort/data
parallel; also the FSDP axis for trillion-scale expert FFNs), optional
`pod` axis (replica aggregation across pods). ``param_specs`` in
repro.models.params enforces per-param single-claim + divisibility, so the
rules here can be declared optimistically.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def mesh_rules(cfg, mesh: Mesh) -> dict:
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    rules = {
        "embed": None,
        "vocab": "model",
        "ff": "model",
        "heads": "model",
        # KV weights replicate when n_kv doesn't divide the model axis
        # (param_specs skips non-divisible dims); sharding head_dim instead
        # was tried and causes SPMD involuntary remats at the GQA einsum
        # (q heads sharded vs k head_dim sharded) — see EXPERIMENTS.md §Perf.
        "kv_heads": "model",
        "head_dim": None,
        "experts": "model",
        "expert_ff": "data" if cfg.fsdp_ff else "model",
        "ssm_inner": "model",
        "ssm_heads": "model",
        "ssm_state": None,
        "conv_k": None,
        "lora": None,
        "rope_dim": None,
        "none": None,
    }
    for ax, size in axis_sizes.items():
        rules[("_size", ax)] = size
    return rules


def data_axes(mesh: Mesh):
    """Mesh axes the global batch shards over."""
    return (("pod", "data") if "pod" in mesh.axis_names else ("data",))


def model_param_specs(cfg, mesh: Mesh):
    from repro.models.params import param_specs
    from repro.models.transformer import model_defs
    return param_specs(model_defs(cfg), mesh_rules(cfg, mesh))


def batch_spec(mesh: Mesh, ndim: int, *, batch_size: int | None = None):
    """P(batch_sharded, None, ...) — falls back to replicated batch when the
    global batch doesn't divide the data axes (e.g. long_500k B=1)."""
    dp = data_axes(mesh)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in dp:
        n *= axis_sizes[a]
    first = dp if (batch_size is None or batch_size % n == 0) else None
    return P(first, *([None] * (ndim - 1)))


def cache_specs(cfg, mesh: Mesh, caches_abstract, batch: int):
    """Sharding for decode caches: batch over data axes when divisible,
    otherwise shard the sequence dim (long-context, batch=1) — see
    DESIGN.md §6. SSM states / window ring buffers stay tiny: batch or
    replicated."""
    dp = data_axes(mesh)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    mdl = axis_sizes.get("model", 1)
    n = 1
    for a in dp:
        n *= axis_sizes[a]
    batch_ok = batch % n == 0

    def model_dim(arr):
        """Pick the cache dim to shard over `model`: kv heads (dim 2 of
        (B,S,K,D)) when divisible, else head_dim; MLA latent rank (dim 2
        of (B,S,R)); SSM heads (dim 1 of (B,H,P,N)). Without this, decode
        caches replicate model-axis-wide: stablelm decode_32k measured
        86.6 GB/chip -> 5.6 GB after (EXPERIMENTS.md §Perf-cache)."""
        if arr.ndim == 4 and arr.shape[2] % mdl == 0:
            return 2
        if arr.ndim == 4 and arr.shape[3] % mdl == 0:
            return 3
        if arr.ndim == 3 and arr.shape[2] % mdl == 0:
            return 2
        return None

    def spec_for(path_leaf):
        name, arr = path_leaf
        nd = arr.ndim
        if nd == 1:                          # slot_pos
            return P(None)
        md = model_dim(arr) if name in ("k", "v", "latent") else None
        spec = [None] * nd
        if batch_ok:
            spec[0] = dp
        elif (name in ("k", "v", "latent", "k_rope")
              and arr.shape[1] % n == 0):
            # batch=1 long-context: shard the sequence dim instead
            spec[1] = dp
        if md is not None and spec[md] is None:
            spec[md] = "model"
        return P(*spec)

    out = []
    for layer in caches_abstract:
        out.append({k: spec_for((k, v)) for k, v in layer.items()})
    return out


def shard_params(params, specs, mesh: Mesh):
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), params, specs)
