"""Parameter definition machinery.

Every module declares its parameters ONCE as a pytree of ``ParamDef``
(shape + logical axes + init kind). From that single source we derive:

- ``init_params``     — materialized arrays (seeded, correct dtype)
- ``abstract_params`` — ShapeDtypeStructs for the no-allocation dry-run
- ``param_specs``     — PartitionSpecs via logical-axis -> mesh-axis rules

Logical axis names used across the model zoo:
  embed, vocab, heads, kv_heads, head_dim, ff, experts, expert_ff,
  ssm_inner, ssm_state, ssm_heads, conv_k, lora, rope_dim, none
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class ParamDef(NamedTuple):
    shape: tuple
    axes: tuple                 # logical axis name per dim
    init: str = "fan_in"        # fan_in | normal | zeros | ones | ssm_a | ssm_dt
    scale: float = 0.02
    dtype: str = ""             # '' -> model param_dtype

    def nbytes(self, default_dtype: str) -> int:
        dt = jnp.dtype(self.dtype or default_dtype)
        return math.prod(self.shape) * dt.itemsize


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def tree_map_defs(fn, defs):
    return jax.tree.map(fn, defs, is_leaf=is_def)


def _materialize(d: ParamDef, key, param_dtype: str):
    dtype = jnp.dtype(d.dtype or param_dtype)
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "normal":
        return (jax.random.normal(key, d.shape, jnp.float32) * d.scale).astype(dtype)
    if d.init == "fan_in":
        fan_in = d.shape[0] if d.shape else 1
        std = 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, d.shape, jnp.float32) * std).astype(dtype)
    if d.init == "conv":         # HWIO conv weight: fan_in = H*W*I
        fan_in = math.prod(d.shape[:-1]) if len(d.shape) > 1 else 1
        std = math.sqrt(2.0 / max(fan_in, 1))
        return (jax.random.normal(key, d.shape, jnp.float32) * std).astype(dtype)
    if d.init == "ssm_a":        # A_log: A in [1, 16]
        u = jax.random.uniform(key, d.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dtype)
    if d.init == "ssm_dt":       # dt_bias: softplus^-1(dt), dt in [1e-3, 1e-1]
        u = jax.random.uniform(key, d.shape, jnp.float32, math.log(1e-3),
                               math.log(1e-1))
        dt = jnp.exp(u)
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)
    raise ValueError(f"unknown init {d.init!r}")


def init_params(defs, key, param_dtype: str = "float32"):
    """Materialize a ParamDef tree into arrays with per-leaf fold_in keys."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, max(len(leaves), 1))
    out = [_materialize(d, k, param_dtype) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)


def abstract_params(defs, param_dtype: str = "float32"):
    """ShapeDtypeStruct tree (no allocation) for lowering."""
    return tree_map_defs(
        lambda d: jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype or param_dtype)),
        defs)


def param_specs(defs, rules: dict):
    """PartitionSpec tree from logical-axis rules {logical: mesh_axis|None}.

    A mesh axis may be claimed by at most one dim per param; later dims
    fall back to replication if the axis is already used.
    """
    def to_spec(d: ParamDef):
        used = set()
        spec = []
        for ax, size in zip(d.axes, d.shape):
            m = rules.get(ax)
            if m is None or m in used or size == 0:
                spec.append(None)
                continue
            msize = rules.get(("_size", m), 0)
            if msize and size % msize != 0:
                spec.append(None)
                continue
            used.add(m)
            spec.append(m)
        return P(*spec)
    return tree_map_defs(to_spec, defs)


def count_params(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=is_def)
    return sum(math.prod(d.shape) for d in leaves)


def param_bytes(defs, param_dtype: str) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=is_def)
    return sum(d.nbytes(param_dtype) for d in leaves)
