"""Composable decoder assembly.

Blocks are built from the config's (mixer, ffn) pattern; the stack exposes
range-application (``apply_blocks(lo, hi)``) which is what S²FL's sliding
split consumes: the client portion is ``embed + blocks[:s]``, the server
portion is ``blocks[s:] + final_norm + head`` (see repro.core.split).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (cross_entropy, embed, embed_defs, head_defs,
                                 mlp, mlp_defs, rmsnorm, rmsnorm_defs)
from repro.models.params import abstract_params, init_params


# ---------------------------------------------------------------------------
# defs
# ---------------------------------------------------------------------------
def _block_defs(cfg, mixer: str, ffn: str):
    d = cfg.d_model
    defs = {"norm1": rmsnorm_defs(d)}
    if mixer == "ssm":
        defs["mixer"] = ssm_mod.ssm_defs(cfg)
    elif mixer in ("attn", "swa"):
        defs["mixer"] = attn_mod.attn_defs(cfg)
    elif mixer == "shared_attn":
        pass                                   # params live in cfg-level slot
    else:
        raise ValueError(mixer)
    if ffn == "dense":
        defs["norm2"] = rmsnorm_defs(d)
        defs["ffn"] = mlp_defs(d, cfg.d_ff)
    elif ffn == "moe":
        defs["norm2"] = rmsnorm_defs(d)
        defs["ffn"] = moe_mod.moe_defs(cfg)
    return defs


def model_defs(cfg):
    defs = {
        "embed": embed_defs(cfg.vocab_padded, cfg.d_model),
        "blocks": [_block_defs(cfg, m, f) for m, f in cfg.pattern()],
        "final_norm": rmsnorm_defs(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        defs["head"] = head_defs(cfg.d_model, cfg.vocab_padded)
    if any(m == "shared_attn" for m, _ in cfg.pattern()):
        defs["shared_attn"] = {
            "mixer": attn_mod.attn_defs(cfg),
            "norm2": rmsnorm_defs(cfg.d_model),
            "ffn": mlp_defs(cfg.d_model, cfg.d_ff),
        }
    return defs


def init_model(cfg, key):
    return init_params(model_defs(cfg), key, cfg.param_dtype)


def abstract_model(cfg):
    return abstract_params(model_defs(cfg), cfg.param_dtype)


# ---------------------------------------------------------------------------
# forward pieces (split-aware)
# ---------------------------------------------------------------------------
def apply_embed(cfg, params, tokens, prefix_embeds=None):
    """tokens: (B,S) int32; optional prefix_embeds (B,P,d) from a modality
    frontend stub. Returns hidden (B, P+S, d)."""
    h = embed(params["embed"], tokens, jnp.dtype(cfg.dtype))
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    return h


def _apply_block_kind(cfg, mixer, ffn, bp, shared, h, positions, cache,
                      cache_index):
    """One block of a given (mixer, ffn) kind with explicit params `bp`
    (and the config-level shared-attention params for zamba2-style
    blocks). The indexed and scanned paths both route through here."""
    aux = jnp.zeros((), jnp.float32)

    if mixer == "shared_attn":
        sp = shared
        a, cache = attn_mod.attn_apply(cfg, "attn", sp["mixer"],
                                       rmsnorm(bp["norm1"], h, cfg.norm_eps),
                                       positions, cache, cache_index)
        h = h + a
        f = mlp(sp["ffn"], rmsnorm(sp["norm2"], h, cfg.norm_eps), cfg.act)
        return h + f, cache, aux

    if mixer == "ssm":
        a, cache = ssm_mod.ssm_apply(cfg, bp["mixer"],
                                     rmsnorm(bp["norm1"], h, cfg.norm_eps),
                                     cache)
    else:
        a, cache = attn_mod.attn_apply(cfg, mixer, bp["mixer"],
                                       rmsnorm(bp["norm1"], h, cfg.norm_eps),
                                       positions, cache, cache_index)
    h = h + a

    if ffn == "dense":
        h = h + mlp(bp["ffn"], rmsnorm(bp["norm2"], h, cfg.norm_eps), cfg.act)
    elif ffn == "moe":
        f, aux = moe_mod.moe_apply(cfg, bp["ffn"],
                                   rmsnorm(bp["norm2"], h, cfg.norm_eps))
        h = h + f
    return h, cache, aux


def _apply_one_block(cfg, params, i, h, positions, cache, cache_index):
    mixer, ffn = cfg.pattern()[i]
    return _apply_block_kind(cfg, mixer, ffn, params["blocks"][i],
                             params.get("shared_attn"), h, positions,
                             cache, cache_index)


def _remat_policy(cfg):
    """'' -> full recompute (minimum memory); 'dots' -> keep matmul
    outputs resident and only recompute elementwise ops (halves the
    re-read bytes of weight-heavy blocks at ~1.5x activation memory —
    the §Perf remat iteration)."""
    if getattr(cfg, "remat_policy", "") == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return None


def _segments(cfg, lo: int, hi: int):
    """Maximal runs of identical (mixer, ffn) kind in [lo, hi)."""
    pat = cfg.pattern()
    runs, i = [], lo
    while i < hi:
        j = i
        while j < hi and pat[j] == pat[i]:
            j += 1
        runs.append((i, j, pat[i]))
        i = j
    return runs


_SCAN_MIN_RUN = 3


def _apply_blocks_scanned(cfg, params, h, lo, hi, positions, train):
    """Cacheless path with jax.lax.scan over runs of identical blocks:
    HLO size (and compile time) become O(#distinct block kinds) instead of
    O(n_layers) — essential for the 61-layer MoE / 62-layer dense dry-runs
    on the 512-way mesh (EXPERIMENTS.md §Perf-compile). Per-layer params
    are stacked inside the jitted function, so the param pytree (and its
    shardings) is unchanged at the jit boundary."""
    aux_sum = jnp.zeros((), jnp.float32)
    shared = params.get("shared_attn")
    for (i, j, (mixer, ffn)) in _segments(cfg, lo, hi):
        n = j - i
        if n < _SCAN_MIN_RUN:
            for k in range(i, j):
                h, _, aux = _apply_block_kind(cfg, mixer, ffn,
                                              params["blocks"][k], shared,
                                              h, positions, None, None)
                aux_sum = aux_sum + aux
            continue
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                               *[params["blocks"][k] for k in range(i, j)])

        def body(hh, bp, mixer=mixer, ffn=ffn):
            def blk(hh_, bp_):
                out, _, aux = _apply_block_kind(cfg, mixer, ffn, bp_,
                                                shared, hh_, positions,
                                                None, None)
                return out, aux
            if train and cfg.remat:
                blk = jax.checkpoint(blk, policy=_remat_policy(cfg))
            out, aux = blk(hh, bp)
            return out, aux

        h, auxs = jax.lax.scan(body, h, stacked)
        aux_sum = aux_sum + auxs.sum()
    return h, None, aux_sum


def apply_blocks(cfg, params, h, lo: int, hi: int, positions,
                 caches=None, cache_index=None, train: bool = False):
    """Apply blocks [lo, hi). caches: per-layer list (len n_layers) or None.
    Returns (h, caches, aux_sum). When cfg.scan_layers and no caches are
    involved, identical-block runs are scanned (see _apply_blocks_scanned).
    """
    if caches is None and getattr(cfg, "scan_layers", False):
        return _apply_blocks_scanned(cfg, params, h, lo, hi, positions,
                                     train)
    aux_sum = jnp.zeros((), jnp.float32)
    caches = list(caches) if caches is not None else None
    for i in range(lo, hi):
        c_i = caches[i] if caches is not None else None
        fn = _apply_one_block
        if train and cfg.remat:
            fn = jax.checkpoint(_apply_one_block, static_argnums=(0, 2),
                                policy=_remat_policy(cfg))
        h, c_i, aux = fn(cfg, params, i, h, positions, c_i, cache_index)
        if caches is not None:
            caches[i] = c_i
        aux_sum = aux_sum + aux
    return h, caches, aux_sum


def apply_head(cfg, params, h):
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    if cfg.tie_embeddings:
        w = params["embed"]["tok"].astype(h.dtype)
        return h @ w.T
    return h @ params["head"]["w"].astype(h.dtype)


# ---------------------------------------------------------------------------
# whole-model entry points
# ---------------------------------------------------------------------------
def forward(cfg, params, tokens, prefix_embeds=None, train: bool = False):
    """Full forward: logits (B, P+S, vocab_padded), aux loss."""
    h = apply_embed(cfg, params, tokens, prefix_embeds)
    positions = jnp.arange(h.shape[1], dtype=jnp.int32)
    h, _, aux = apply_blocks(cfg, params, h, 0, cfg.n_layers, positions,
                             train=train)
    return apply_head(cfg, params, h), aux


def lm_loss(cfg, params, batch, train: bool = True):
    """batch: {'tokens': (B,S), 'labels': (B,S), optional 'prefix': (B,P,d)}.
    labels[i] is the target for position i (already shifted); -100 ignored."""
    logits, aux = forward(cfg, params, batch["tokens"],
                          batch.get("prefix"), train=train)
    P = logits.shape[1] - batch["tokens"].shape[1]
    if P:
        logits = logits[:, P:]
    ce = cross_entropy(logits, batch["labels"], cfg.vocab_size)
    return ce + aux, {"ce": ce, "aux": aux}


def init_caches(cfg, batch: int, max_len: int):
    dtype = jnp.dtype(cfg.dtype)
    caches = []
    for mixer, _ in cfg.pattern():
        if mixer == "ssm":
            caches.append(ssm_mod.init_ssm_cache(cfg, batch, dtype))
        else:
            caches.append(attn_mod.init_attn_cache(cfg, mixer, batch,
                                                   max_len, dtype))
    return caches


def prefill(cfg, params, tokens, max_len: int, prefix_embeds=None):
    """Run the prompt, build caches. Returns (last_logits, caches, n_prefill)."""
    h = apply_embed(cfg, params, tokens, prefix_embeds)
    S = h.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    caches = init_caches(cfg, tokens.shape[0], max_len)
    h, caches, _ = apply_blocks(cfg, params, h, 0, cfg.n_layers, positions,
                                caches=caches, cache_index=None)
    logits = apply_head(cfg, params, h[:, -1:])
    return logits, caches, S


def decode_step(cfg, params, token, caches, index):
    """One decode step. token: (B,1) int32, index: scalar int32 (current
    position). Returns (logits (B,1,V), caches)."""
    h = apply_embed(cfg, params, token)
    positions = index[None].astype(jnp.int32) if index.ndim == 0 else index
    h, caches, _ = apply_blocks(cfg, params, h, 0, cfg.n_layers, positions,
                                caches=caches, cache_index=index)
    return apply_head(cfg, params, h), caches
