"""Modality frontend STUBS (the one carve-out to "build everything").

Per the brief, [audio] and [vlm] entries specify the transformer BACKBONE
only: the mel-spectrogram/EnCodec conv feature extractor (audio) and the
InternViT vision encoder + projector (vlm) are stubs whose role is to
provide precomputed frame/patch embeddings of the right shape. At training
time the synthetic pipeline generates them; at dry-run time input_specs()
provides ShapeDtypeStructs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def frontend_embed_shape(cfg, batch: int):
    assert cfg.frontend, cfg.name
    return (batch, cfg.n_frontend_tokens, cfg.d_model)


def frontend_embed_spec(cfg, batch: int):
    return jax.ShapeDtypeStruct(frontend_embed_shape(cfg, batch),
                                jnp.dtype(cfg.dtype))


def synth_frontend_embeds(cfg, key, batch: int):
    """Stand-in for InternViT patch embeddings / EnCodec frame embeddings."""
    return (jax.random.normal(key, frontend_embed_shape(cfg, batch),
                              jnp.float32) * 0.02).astype(jnp.dtype(cfg.dtype))
