from repro.models.api import SplitModel, get_subtree

__all__ = ["SplitModel", "get_subtree"]
