"""Pure-jnp oracles for the fused cohort-compression kernels. Same math
as kernel.py, no Pallas — the numerics tests assert the Pallas pair
matches these, and the batched comm path falls back to them when the
kernel path is disabled (the backend selection in
kernels/int8_quant/ops.py: oracle everywhere but TPU by default)."""
from __future__ import annotations

import jax.numpy as jnp

_QMAX = 127.0


def int8_roundtrip_ref(x, dtype=jnp.float32):
    """x: (R, G) float group rows -> dequantize(quantize(x)). Identical
    math to int8_quantize_ref + int8_dequantize_ref composed."""
    x = x.astype(jnp.float32)
    mn = jnp.min(x, axis=1, keepdims=True)
    mx = jnp.max(x, axis=1, keepdims=True)
    scale = jnp.maximum((mx - mn) / (2.0 * _QMAX), 1e-12)
    zp = -_QMAX - mn / scale
    q = jnp.clip(jnp.round(x / scale + zp), -_QMAX, _QMAX)
    return (scale * (q - zp)).astype(dtype)


def sparse_combine_ref(y, mask, scale):
    """(delivered, residual) = (y * mask * scale, y - delivered)."""
    delivered = (y.astype(jnp.float32) * mask
                 * jnp.float32(scale)).astype(y.dtype)
    return delivered, (y - delivered).astype(y.dtype)
