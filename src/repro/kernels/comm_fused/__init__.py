from repro.kernels.comm_fused.ops import (  # noqa: F401
    fused_cast_roundtrip, fused_int8_roundtrip, fused_sparse_roundtrip,
    int8_group_geometry)
