"""Public wrappers for the fused cohort-compression kernels.

Input convention: a cohort's cut tensors are stacked into one ``(D, N)``
buffer (one row per device, tensors flattened). Each wrapper runs the
whole codec roundtrip — residual add, select/quantize, decode, residual
update ``r' = (x + r) - decode(encode(x + r))`` — as ONE jitted call per
cohort, donated on accelerator backends so the stacked input buffer is
reused in place (donation is a no-op on CPU, where jax ignores it).

Backend selection follows kernels/int8_quant/ops.py exactly
(``kernel_enabled`` / ``interpret_mode``: real Pallas kernels on TPU or
REPRO_COMM_KERNEL=1, the jnp oracles elsewhere), so one env var governs
the sequential and the batched compression paths alike.

Numerics contract (tested): every wrapper is element-for-element the
same math as the sequential per-device codec path in
``repro.comm.codecs`` — the batched channel asserts ≤1e-6 equivalence
on delivered tensors and residuals, and bit-equal wire bytes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.comm_fused.kernel import (int8_roundtrip_pallas,
                                             sparse_combine_pallas)
from repro.kernels.comm_fused.ref import (int8_roundtrip_ref,
                                          sparse_combine_ref)
from repro.kernels.int8_quant.ops import (GROUP, interpret_mode,
                                          kernel_enabled)


def _donate(*argnums):
    """Donate the stacked cohort buffers on accelerators; on CPU jax
    ignores donation with a warning per call site, so skip it there."""
    return argnums if jax.default_backend() != "cpu" else ()


def _as_group_rows(x2, group: int):
    """(D, N) -> (D * R, g) group rows, row-major so each device's
    values stay consecutive; per-row edge padding mirrors
    int8_quant.ops._as_groups per device (zero-padding would drag the
    tail group's min/max toward 0)."""
    d, n = x2.shape
    g = max(1, min(group, n))
    pad = (-n) % g
    if pad:
        x2 = jnp.pad(x2, ((0, 0), (0, pad)), mode="edge")
    return x2.reshape(d * ((n + pad) // g), g)


def int8_group_geometry(n: int, group: int = GROUP):
    """(values-per-group g, groups-per-device R) for an N-value device
    row — the shape the wire bytes are metered from (R * g payload
    bytes + R group-metadata records), identical to the sequential
    Int8Codec accounting."""
    g = max(1, min(group, int(n)))
    return g, -(-int(n) // g)


# --------------------------------------------------------------- int8
@functools.lru_cache(maxsize=None)
def _int8_fn(ef: bool, group: int):
    use_k, interp = kernel_enabled(), interpret_mode()

    def rt(y):
        d, n = y.shape
        rows = _as_group_rows(y, group)
        if use_k:
            dq = int8_roundtrip_pallas(rows, dtype=y.dtype,
                                       interpret=interp)
        else:
            dq = int8_roundtrip_ref(rows, dtype=y.dtype)
        return dq.reshape(d, -1)[:, :n]

    if ef:
        def fn(x, r):
            y = x + r.astype(x.dtype)
            delivered = rt(y)
            return delivered, y - delivered
        return jax.jit(fn, donate_argnums=_donate(0, 1))

    def fn(x):
        return rt(x), None
    return jax.jit(fn, donate_argnums=_donate(0))


def fused_int8_roundtrip(x, r=None, group: int = GROUP):
    """x: (D, N) stacked cohort; r: matching residual stack or None.
    Returns (delivered, new_residual_or_None), one jitted call."""
    fn = _int8_fn(r is not None, group)
    return fn(x, r) if r is not None else fn(x)


# -------------------------------------------------------- sparsifiers
@functools.lru_cache(maxsize=None)
def _sparse_fn(k: int, ef: bool, has_idx: bool):
    use_k, interp = kernel_enabled(), interpret_mode()

    def rt(y, idx, scale):
        d, n = y.shape
        y32 = y.astype(jnp.float32)
        if idx is None:
            # top-k selection rides XLA's native batched operator —
            # row-wise identical to the sequential per-device top_k
            idx = jax.lax.top_k(jnp.abs(y32), k)[1]
        rows = jnp.arange(d)[:, None]
        mask = jnp.zeros((d, n), jnp.float32).at[rows, idx].set(1.0)
        if use_k:
            delivered, res = sparse_combine_pallas(y32, mask, scale,
                                                   interpret=interp)
        else:
            delivered, res = sparse_combine_ref(y32, mask, scale)
        return delivered.astype(y.dtype), res

    if ef:
        def fn(x, r, *a):
            y = x + r.astype(x.dtype)
            delivered, res = rt(y, a[0] if has_idx else None, a[-1])
            # the fused kernel already emitted the residual dual; it is
            # exact when y is f32 (y32 IS y), recompute otherwise
            new_r = res if y.dtype == jnp.float32 else y - delivered
            return delivered, new_r
        return jax.jit(fn, donate_argnums=_donate(0, 1))

    def fn(x, *a):
        idx = a[0] if has_idx else None
        delivered, _ = rt(x, idx, a[-1])
        return delivered, None
    return jax.jit(fn, donate_argnums=_donate(0))


def fused_sparse_roundtrip(x, r=None, *, k: int, scale=1.0, indices=None):
    """x: (D, N) stacked cohort; keep k entries per row — the k
    largest-magnitude (top-k) when ``indices`` is None, else the given
    (D, k) index rows (rand-k; drawn host-side to preserve the codec's
    per-call counter stream). ``scale`` multiplies survivors (n/k for
    the unbiased rand-k estimator). Returns (delivered,
    new_residual_or_None)."""
    fn = _sparse_fn(int(k), r is not None, indices is not None)
    args = (x,) + ((r,) if r is not None else ())
    if indices is not None:
        args += (jnp.asarray(indices),)
    return fn(*args, jnp.float32(scale))


# --------------------------------------------------------------- cast
@functools.lru_cache(maxsize=None)
def _cast_fn(wire_dtype_name: str, ef: bool):
    wire = jnp.dtype(wire_dtype_name)
    # a downcast roundtrip is a single fused XLA convert pair — no
    # Pallas kernel needed, but it rides the same one-call-per-cohort
    # contract (and the residual update fuses into the same program)

    def rt(y):
        return y.astype(wire).astype(y.dtype)

    if ef:
        def fn(x, r):
            y = x + r.astype(x.dtype)
            delivered = rt(y)
            return delivered, y - delivered
        return jax.jit(fn, donate_argnums=_donate(0, 1))

    def fn(x):
        return rt(x), None
    return jax.jit(fn, donate_argnums=_donate(0))


def fused_cast_roundtrip(x, r=None, *, wire_dtype):
    """bf16/fp16 wire downcast over a stacked (D, N) cohort."""
    fn = _cast_fn(jnp.dtype(wire_dtype).name, r is not None)
    return fn(x, r) if r is not None else fn(x)
