"""Fused Pallas kernels for the batched cohort-compression hot path.

The sequential comm path runs one jnp dispatch chain per (device,
tensor): quantize kernel -> dequantize kernel (int8), or top-k ->
gather -> scatter (sparsifiers), with the error-feedback residual add /
update as separate elementwise passes around them. These kernels fuse
each roundtrip into a single VMEM pass over a stacked cohort buffer:

``int8_roundtrip_pallas``   (R, G) group rows -> dequantized rows in ONE
                            kernel: row min/max, scale/zp, quantize,
                            dequantize — q/scale/zp never materialize in
                            HBM (the wire bytes they would occupy are
                            priced analytically by the channel).
``sparse_combine_pallas``   given the cohort buffer y = x + r and the
                            survivor mask, emit the delivered tensor
                            ``y * mask * scale`` and the residual dual
                            ``r' = y - delivered`` in one pass (two
                            outputs, one read).

Top-k *selection* itself stays on ``jax.lax.top_k`` (XLA's native
batched operator — sorting networks inside a Pallas TPU kernel are not
supported); everything around it is fused here. The jnp oracles live in
ref.py; ops.py picks kernel vs oracle with the same backend logic as
kernels/int8_quant (REPRO_COMM_KERNEL / REPRO_PALLAS_INTERPRET).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_QMAX = 127.0               # same symmetric affine range as int8_quant


def _int8_roundtrip_kernel(x_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)                  # (BR, G)
    mn = jnp.min(x, axis=1, keepdims=True)
    mx = jnp.max(x, axis=1, keepdims=True)
    scale = jnp.maximum((mx - mn) / (2.0 * _QMAX), 1e-12)
    zp = -_QMAX - mn / scale                            # maps mn -> -127
    q = jnp.clip(jnp.round(x / scale + zp), -_QMAX, _QMAX)
    out_ref[...] = (scale * (q - zp)).astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "interpret", "dtype"))
def int8_roundtrip_pallas(x, *, block_rows: int = 256,
                          dtype=jnp.float32, interpret: bool = True):
    """x: (R, G) float group rows -> dequantize(quantize(x)) of the same
    shape, numerically identical to int8_quantize_pallas followed by
    int8_dequantize_pallas but in one kernel with no intermediate
    q/scale/zp buffers. R need not be a multiple of block_rows."""
    r, g = x.shape
    br = min(block_rows, r)
    nb = pl.cdiv(r, br)
    pad = nb * br - r
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        _int8_roundtrip_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((br, g), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, g), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb * br, g), dtype),
        interpret=interpret,
    )(x)
    return out[:r]


def _sparse_combine_kernel(y_ref, mask_ref, scale_ref, out_ref, res_ref):
    y = y_ref[...]
    delivered = (y.astype(jnp.float32) * mask_ref[...]
                 * scale_ref[0]).astype(out_ref.dtype)
    out_ref[...] = delivered
    res_ref[...] = (y - delivered).astype(res_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "interpret"))
def sparse_combine_pallas(y, mask, scale, *, block_rows: int = 64,
                          interpret: bool = True):
    """y: (D, N) cohort buffer (already residual-added); mask: (D, N)
    0/1 survivor mask; scale: scalar (1.0 for top-k, n/k for unbiased
    rand-k). Returns (delivered, residual) = (y * mask * scale,
    y - delivered) in one fused pass."""
    d, n = y.shape
    br = min(block_rows, d)
    nb = pl.cdiv(d, br)
    pad = nb * br - d
    if pad:
        y = jnp.pad(y, ((0, pad), (0, 0)))
        mask = jnp.pad(mask, ((0, pad), (0, 0)))
    scale = jnp.asarray(scale, jnp.float32).reshape(1)
    out, res = pl.pallas_call(
        _sparse_combine_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((br, n), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb * br, n), y.dtype),
            jax.ShapeDtypeStruct((nb * br, n), y.dtype),
        ],
        interpret=interpret,
    )(y, mask, scale)
    return out[:d], res[:d]
