"""Jit'd public wrapper: model-layout adapter around the flash kernel.

On CPU (this container) the kernel body runs under interpret=True; on a
real TPU set REPRO_PALLAS_INTERPRET=0 to lower natively.
"""
from __future__ import annotations

import os

from repro.kernels.flash_attention.kernel import flash_attention_fwd

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") == "1"


def flash_attention(q, k, v, q_pos=None, k_pos=None, *, window: int = 0,
                    causal: bool = True):
    """Model layout: q (B,S,H,D), k/v (B,T,K,D) -> (B,S,H,Dv).

    Assumes contiguous positions starting at 0 (train/prefill paths).
    """
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    # head order after transpose is (K, G) with G fastest, so the kernel's
    # kv index b // G maps q head (k*G + g) to kv head k.
    qf = q.transpose(0, 2, 1, 3).reshape(B * K * G, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * K, T, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * K, T, v.shape[-1])
    out = flash_attention_fwd(qf, kf, vf, causal=causal, window=window,
                              groups=G, interpret=INTERPRET)
    out = out.reshape(B, K, G, S, -1).reshape(B, H, S, -1)
    return out.transpose(0, 2, 1, 3)
