"""Flash attention Pallas TPU kernel (causal / sliding-window, GQA).

Schedule: grid (batch*kv_head*group, n_q_blocks, n_kv_blocks) with the kv
axis innermost-sequential; online-softmax running max / denominator / output
accumulator live in VMEM scratch across kv steps. Block shapes are
MXU-aligned (128 multiples) when the problem shape allows.

VMEM budget per step (bf16 inputs, f32 accum):
  q (bq, D) + k,v (bk, D) + scratch m,l (bq,128 lanes) + acc (bq, D) f32
  defaults bq=bk=128, D<=256  ->  well under the ~16 MB/core budget.

Positions are implicit (q and k both start at position 0, contiguous) —
this matches the train/prefill paths that call it. Fully-masked q blocks
(outside a sliding window) are skipped via pl.when.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: int, bq: int, bk: int,
                 nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq
    k_start = ki * bk
    # block-level skip: causal => skip blocks fully above the diagonal;
    # window => skip blocks fully left of the window.
    run = True
    if causal:
        run = k_start <= q_start + bq - 1
    if window:
        run = jnp.logical_and(run, k_start + bk - 1 >= q_start - window + 1) \
            if causal else (k_start + bk - 1 >= q_start - window + 1)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)                  # (bq, D)
        k = k_ref[0].astype(jnp.float32)                  # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_scr[:, 0] * alpha + p.sum(axis=-1)
        v = v_ref[0].astype(jnp.float32)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[:, 0] = m_new
        l_scr[:, 0] = l_new

    @pl.when(ki == nk - 1)
    def _done():
        l = l_scr[:, 0]
        l = jnp.where(l == 0, 1.0, l)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def _pick_block(S: int, pref: int = 128) -> int:
    for b in (pref, 256, 128, 64, 32, 16, 8):
        if S % b == 0 and b <= S:
            return b
    return S


@functools.partial(jax.jit, static_argnames=("causal", "window", "groups",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        groups: int = 1, block_q: int = 128,
                        block_k: int = 128, interpret: bool = True):
    """q: (BHq, S, D) with BHq = B*K*G; k, v: (BK, T, D), BK = BHq//groups.

    Returns (BHq, S, Dv). `groups` is the GQA group count G.
    """
    BH, S, D = q.shape
    BK, T, Dv = v.shape[0], k.shape[1], v.shape[-1]
    bq = _pick_block(S, block_q)
    bk = _pick_block(T, block_k)
    nq, nk = S // bq, T // bk
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(_attn_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j, g=groups: (b // g, j, 0)),
            pl.BlockSpec((1, bk, Dv), lambda b, i, j, g=groups: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, Dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
