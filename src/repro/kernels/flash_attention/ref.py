"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  groups: int = 1):
    """q: (BHq, S, D); k, v: (BK, T, D); BHq = BK * groups."""
    BH, S, D = q.shape
    T = k.shape[1]
    k = jnp.repeat(k, groups, axis=0)
    v = jnp.repeat(v, groups, axis=0)
    s = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    # fully-masked rows -> 0 (matches kernel's l==0 guard)
    any_valid = mask.any(axis=-1)
    w = jnp.where(any_valid[None, :, None], w, 0.0)
    return jnp.einsum("bst,btd->bsd", w, v.astype(jnp.float32)).astype(q.dtype)
