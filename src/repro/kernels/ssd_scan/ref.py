"""Oracle for the SSD scan kernel = the model's pure-jnp chunked dual form
(itself validated against the sequential recurrence in tests)."""
from repro.models.ssm import ssd_scan_ref  # noqa: F401
