"""Mamba2 SSD chunked-scan Pallas TPU kernel.

Grid: (batch, heads, n_chunks) with the chunk axis innermost-sequential;
the inter-chunk SSM state (P x N, f32) is carried in VMEM scratch across
chunk steps (TPU grid iteration is sequential, so scratch persists).

Per chunk step (l = chunk length):
  1. intra-chunk quadratic term  y_diag = (L ∘ C Bᵀ) (dt ∘ x)
  2. inter-chunk contribution    y_off  = exp(cumsum dA) * (C state)
  3. state update                state  = exp(sum dA) * state + tailᵀ x

VMEM per step: x (l,P) + B,C (l,N) + L (l,l) f32 + state (P,N) f32 —
with l=128, P=64..128, N=64..128 this is < 0.5 MB, comfortably in VMEM;
the MXU sees (l,l)x(l,P) and (l,N)x(N,P) matmuls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, init_ref,
                y_ref, fs_ref, state_scr, *, l: int, nc: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = init_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, :, 0].astype(jnp.float32)               # (l, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)             # (l,)
    A = a_ref[0]                                         # scalar
    B = b_ref[0].astype(jnp.float32)                     # (l, N)
    C = c_ref[0].astype(jnp.float32)                     # (l, N)

    dA = dt * A                                          # (l,) <= 0
    cs = jnp.cumsum(dA)                                  # (l,)

    # intra-chunk: L[i,j] = exp(cs_i - cs_j) for j <= i
    seg = cs[:, None] - cs[None, :]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (l, l), 1))
    L = jnp.where(tri, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    W = L * scores                                       # (l, l)
    xdt = x * dt[:, None]                                # (l, P)
    y = jax.lax.dot_general(W, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: y += exp(cs) * (C @ state^T)   state: (P, N)
    state = state_scr[...]
    y_off = jax.lax.dot_general(C, state, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y = y + y_off * jnp.exp(cs)[:, None]
    y_ref[0, :, 0] = y.astype(y_ref.dtype)

    # state update: state = exp(sum dA) * state + sum_m tail_m dt_m x_m B_m
    tail = jnp.exp(cs[-1] - cs) * dt                     # (l,)
    upd = jax.lax.dot_general(x, B * tail[:, None],
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (P, N)
    state_scr[...] = state * jnp.exp(cs[-1]) + upd

    @pl.when(ci == nc - 1)
    def _done():
        fs_ref[0, 0] = state_scr[...].astype(fs_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(x, dt, A, B, C, *, chunk: int = 128,
                    initial_state=None, interpret: bool = True):
    """x: (b,s,h,p), dt: (b,s,h), A: (h,), B/C: (b,s,n).
    Returns (y (b,s,h,p), final_state (b,h,p,n)). s % chunk == 0."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), x.dtype)

    kernel = functools.partial(_ssd_kernel, l=chunk, nc=nc)
    y, fs = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), x.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A.astype(jnp.float32), B, C, initial_state)
    return y, fs
