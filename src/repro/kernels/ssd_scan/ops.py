"""Jit'd public wrapper for the SSD scan kernel."""
from __future__ import annotations

import os

from repro.kernels.ssd_scan.kernel import ssd_scan_pallas

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") == "1"


def ssd_scan(x, dt, A, B, C, *, chunk: int = 128, initial_state=None):
    return ssd_scan_pallas(x, dt, A, B, C, chunk=chunk,
                           initial_state=initial_state, interpret=INTERPRET)
