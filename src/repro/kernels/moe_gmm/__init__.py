from repro.kernels.moe_gmm.ops import expert_ffn, moe_gmm
