"""Grouped expert-FFN Pallas TPU kernel.

Computes, per expert e:  y_e = (act(x_e Wg_e) ∘ (x_e Wu_e)) Wd_e
for capacity-bucketed expert inputs x (E, C, d).

Grid: (E, n_row_tiles, n_ff_tiles) with the ff-tile axis innermost-
sequential; the (bc, d) f32 output accumulator lives in VMEM scratch and
the down-projection is accumulated tile-by-tile, so the (bc, F) hidden
never materializes. Weight tiles stream through VMEM at (d, bf) / (bf, d).

VMEM per step (bf16 weights, f32 accum), defaults bc=128, bf=256:
  x (bc,d) + Wg,Wu (d,bf) + Wd (bf,d) + acc (bc,d) f32
  for d=7168: 1.8 + 2*3.7 + 3.7 + 3.7 MB ≈ 16.6 MB — at the edge, so
  production configs with d=7168 use bf=128 (halves the weight tiles).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, wg_ref, wu_ref, wd_ref, y_ref, acc_scr, *,
                act: str, nf: int):
    fi = pl.program_id(2)

    @pl.when(fi == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[0].astype(jnp.float32)                      # (bc, d)
    wg = wg_ref[0].astype(jnp.float32)                    # (d, bf)
    wu = wu_ref[0].astype(jnp.float32)
    g = jax.lax.dot_general(x, wg, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
    u = jax.lax.dot_general(x, wu, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    h = g * u                                             # (bc, bf)
    wd = wd_ref[0].astype(jnp.float32)                    # (bf, d)
    acc_scr[...] += jax.lax.dot_general(h, wd, (((1,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32)

    @pl.when(fi == nf - 1)
    def _done():
        y_ref[0] = acc_scr[...].astype(y_ref.dtype)


def _pick(n: int, pref: int) -> int:
    for b in (pref, 256, 128, 64, 32, 16, 8):
        if n % b == 0 and b <= n:
            return b
    return n


@functools.partial(jax.jit, static_argnames=("act", "block_c", "block_f",
                                             "interpret"))
def moe_gmm(x, wg, wu, wd, *, act: str = "silu", block_c: int = 128,
            block_f: int = 256, interpret: bool = True):
    """x: (E, C, d); wg/wu: (E, d, F); wd: (E, F, d) -> (E, C, d)."""
    E, C, d = x.shape
    F = wg.shape[-1]
    bc = _pick(C, block_c)
    bf = _pick(F, block_f)
    nc, nf = C // bc, F // bf

    kernel = functools.partial(_gmm_kernel, act=act, nf=nf)
    return pl.pallas_call(
        kernel,
        grid=(E, nc, nf),
        in_specs=[
            pl.BlockSpec((1, bc, d), lambda e, c, f: (e, c, 0)),
            pl.BlockSpec((1, d, bf), lambda e, c, f: (e, 0, f)),
            pl.BlockSpec((1, d, bf), lambda e, c, f: (e, 0, f)),
            pl.BlockSpec((1, bf, d), lambda e, c, f: (e, f, 0)),
        ],
        out_specs=pl.BlockSpec((1, bc, d), lambda e, c, f: (e, c, 0)),
        out_shape=jax.ShapeDtypeStruct((E, C, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, d), jnp.float32)],
        interpret=interpret,
    )(x, wg, wu, wd)
