"""Pure-jnp oracle for the grouped expert-FFN kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def moe_gmm_ref(x, wg, wu, wd, *, act: str = "silu"):
    """x: (E, C, d); wg/wu: (E, d, F); wd: (E, F, d) -> (E, C, d)."""
    actf = jax.nn.silu if act == "silu" else jax.nn.gelu
    xf = x.astype(jnp.float32)
    g = actf(jnp.einsum("ecd,edf->ecf", xf, wg.astype(jnp.float32)))
    u = jnp.einsum("ecd,edf->ecf", xf, wu.astype(jnp.float32))
    y = jnp.einsum("ecf,efd->ecd", g * u, wd.astype(jnp.float32))
    return y.astype(x.dtype)
