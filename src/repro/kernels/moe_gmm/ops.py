"""Jit'd public wrapper: expert FFN on capacity-bucketed inputs."""
from __future__ import annotations

import os

from repro.kernels.moe_gmm.kernel import moe_gmm

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") == "1"


def expert_ffn(p, exp_in, act: str = "silu"):
    """p: moe param dict with w_gate/w_up/w_down (E, ...); exp_in (E, C, d)."""
    d = exp_in.shape[-1]
    block_f = 128 if d > 4096 else 256        # VMEM budget, see kernel.py
    return moe_gmm(exp_in, p["w_gate"], p["w_up"], p["w_down"], act=act,
                   block_f=block_f, interpret=INTERPRET)
