"""Pure-jnp oracle for the int8 affine quantize/dequantize kernels.
Same math as kernel.py, no Pallas — the numerics tests assert the Pallas
pair matches this reference, and the comm codec falls back to it when the
kernel path is disabled."""
from __future__ import annotations

import jax.numpy as jnp

_QMAX = 127.0


def int8_quantize_ref(x):
    """x: (R, C) float -> (q int8, scale f32 (R,1), zp f32 (R,1))."""
    x = x.astype(jnp.float32)
    mn = jnp.min(x, axis=1, keepdims=True)
    mx = jnp.max(x, axis=1, keepdims=True)
    scale = jnp.maximum((mx - mn) / (2.0 * _QMAX), 1e-12)
    zp = -_QMAX - mn / scale
    q = jnp.clip(jnp.round(x / scale + zp), -_QMAX, _QMAX)
    return q.astype(jnp.int8), scale, zp


def int8_dequantize_ref(q, scale, zp, dtype=jnp.float32):
    return (scale * (q.astype(jnp.float32) - zp)).astype(dtype)
