"""Public wrappers for the int8 quant kernels: arbitrary-rank arrays are
flattened and re-grouped into (n_groups, group) rows so each fp32
scale/zp pair covers ``group`` values regardless of the tensor's last-dim
width (CNN feature maps have as few as 16 channels — per-channel-row
metadata would cost 50% of the wire).

The Pallas pair and the jnp reference are numerically identical, so the
default picks whichever is fast for the backend: the real kernel on TPU,
the reference elsewhere (interpret-mode Pallas in the per-step training
hot path would be the slowest option). REPRO_COMM_KERNEL=1/0 forces
either path; REPRO_PALLAS_INTERPRET follows the repo-wide convention."""
from __future__ import annotations

import math
import os

import jax
import jax.numpy as jnp

from repro.kernels.int8_quant.kernel import (int8_dequantize_pallas,
                                             int8_quantize_pallas)
from repro.kernels.int8_quant.ref import (int8_dequantize_ref,
                                          int8_quantize_ref)

_USE_KERNEL = None
_INTERPRET = None


def kernel_enabled() -> bool:
    """True when the comm hot path should run the real Pallas kernels:
    TPU by default, or forced either way via REPRO_COMM_KERNEL=1/0.
    Shared by every comm kernel module (int8_quant, comm_fused) so one
    env var governs the whole compression path."""
    global _USE_KERNEL
    if _USE_KERNEL is None:
        env = os.environ.get("REPRO_COMM_KERNEL", "")
        # lazy: jax.default_backend() initializes the backend
        _USE_KERNEL = (env == "1" if env
                       else jax.default_backend() == "tpu")
    return _USE_KERNEL


def interpret_mode() -> bool:
    """Compiled Pallas on TPU, interpreter elsewhere (unless forced) —
    otherwise default env vars would run interpret-mode Pallas in the
    per-step training hot path on TPU, the slowest option."""
    global _INTERPRET
    if _INTERPRET is None:
        env = os.environ.get("REPRO_PALLAS_INTERPRET", "")
        _INTERPRET = (env == "1" if env
                      else jax.default_backend() != "tpu")
    return _INTERPRET


# original (private) names, kept for existing callers
_kernel_enabled = kernel_enabled
_interpret = interpret_mode


GROUP = 256                     # values per scale/zp pair (8 B / 256 B)


def _as_groups(x, group: int):
    flat = x.reshape(-1)
    g = max(1, min(group, flat.size))
    pad = (-flat.size) % g
    if pad:
        # edge-pad: zero-padding would drag the tail group's min/max
        # toward 0 and blow its quantization step ~range/254 bound
        flat = jnp.pad(flat, (0, pad), mode="edge")
    return flat.reshape(-1, g)


def int8_quantize(x, group: int = GROUP):
    """x: any-rank float array -> (q int8 (R,G), scale (R,1), zp (R,1),
    orig_shape). Rows are groups of ``group`` consecutive values (the
    tail group is zero-padded on the wire)."""
    x2 = _as_groups(x, group)
    if _kernel_enabled():
        q, scale, zp = int8_quantize_pallas(x2, interpret=_interpret())
    else:
        q, scale, zp = int8_quantize_ref(x2)
    return q, scale, zp, x.shape


def int8_dequantize(q, scale, zp, shape, dtype=jnp.float32):
    if _kernel_enabled():
        x = int8_dequantize_pallas(q, scale, zp, dtype=dtype,
                                   interpret=_interpret())
    else:
        x = int8_dequantize_ref(q, scale, zp, dtype=dtype)
    return x.reshape(-1)[:math.prod(shape)].reshape(shape)
