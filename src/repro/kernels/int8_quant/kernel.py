"""Int8 affine quantize / dequantize Pallas TPU kernel pair.

The comm codec hot path (repro.comm): features crossing the cut are
quantized per-row (last axis) to int8 with an affine map

    q  = clip(round(x / scale + zp), -127, 127)        int8
    x' = scale * (q - zp)                              dequant

scale/zp are fp32 per row, so a (R, C) fp32 payload becomes R*C bytes of
int8 plus 8 bytes per row of metadata — a ~4x wire reduction for C >> 8.

Grid: (n_row_blocks,); each step sees a (BR, C) block in VMEM. Row-wise
min/max, the scale/zp computation and the elementwise map are all VPU
work on fully resident blocks, so the kernel is bandwidth-bound — exactly
what we want for a transport codec.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Keep the affine range symmetric (+-127) so zp also fits comfortably in
# fp32 and the dequant map needs no special-casing of -128.
_QMAX = 127.0


def _quantize_kernel(x_ref, q_ref, scale_ref, zp_ref):
    x = x_ref[...].astype(jnp.float32)                  # (BR, C)
    mn = jnp.min(x, axis=1, keepdims=True)              # (BR, 1)
    mx = jnp.max(x, axis=1, keepdims=True)
    scale = jnp.maximum((mx - mn) / (2.0 * _QMAX), 1e-12)
    zp = -_QMAX - mn / scale                            # maps mn -> -127
    q = jnp.clip(jnp.round(x / scale + zp), -_QMAX, _QMAX)
    q_ref[...] = q.astype(jnp.int8)
    scale_ref[...] = scale
    zp_ref[...] = zp


def _dequantize_kernel(q_ref, scale_ref, zp_ref, x_ref):
    q = q_ref[...].astype(jnp.float32)
    x_ref[...] = (scale_ref[...] * (q - zp_ref[...])).astype(x_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def int8_quantize_pallas(x, *, block_rows: int = 256,
                         interpret: bool = True):
    """x: (R, C) float. Returns (q int8 (R,C), scale f32 (R,1),
    zp f32 (R,1)). R need not be a multiple of block_rows (padded rows
    quantize garbage that the wrapper slices off)."""
    r, c = x.shape
    br = min(block_rows, r)
    nb = pl.cdiv(r, br)
    pad = nb * br - r
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    q, scale, zp = pl.pallas_call(
        _quantize_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((br, c), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb * br, c), jnp.int8),
            jax.ShapeDtypeStruct((nb * br, 1), jnp.float32),
            jax.ShapeDtypeStruct((nb * br, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q[:r], scale[:r], zp[:r]


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "interpret", "dtype"))
def int8_dequantize_pallas(q, scale, zp, *, block_rows: int = 256,
                           dtype=jnp.float32, interpret: bool = True):
    """Inverse of int8_quantize_pallas. q: (R, C) int8; scale/zp: (R, 1)."""
    r, c = q.shape
    br = min(block_rows, r)
    nb = pl.cdiv(r, br)
    pad = nb * br - r
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0)))
        scale = jnp.pad(scale, ((0, pad), (0, 0)))
        zp = jnp.pad(zp, ((0, pad), (0, 0)))
    x = pl.pallas_call(
        _dequantize_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb * br, c), dtype),
        interpret=interpret,
    )(q, scale, zp)
    return x[:r]
