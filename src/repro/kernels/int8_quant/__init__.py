from repro.kernels.int8_quant.ops import (  # noqa: F401
    int8_dequantize, int8_quantize)
