"""Figure 8 — ablation of the two mechanisms: S²FL+R (== SFL), S²FL+B
(balance only), S²FL+M (sliding only), S²FL+MB (both). Reduced CPU scale;
the claim checked is that +MB trains and each mechanism runs independently
(accuracy ordering is reported, asserted only loosely due to variance at
this scale)."""
from __future__ import annotations

import os

from benchmarks.common import Timer, emit
from repro.configs import get_config
from repro.core.engine import EngineConfig, S2FLEngine
from repro.data.partition import federate
from repro.data.synthetic import make_image_dataset
from repro.models import SplitModel

ROUNDS = int(os.environ.get("REPRO_BENCH_ROUNDS", "20"))

VARIANTS = {
    "R": dict(use_balance=False, use_sliding=False),   # == SFL
    "B": dict(use_balance=True, use_sliding=False),
    "M": dict(use_balance=False, use_sliding=True),
    "MB": dict(use_balance=True, use_sliding=True),
}


def run():
    ds = make_image_dataset(3000, seed=1)
    test = make_image_dataset(600, seed=42)
    fed = federate(ds, 20, alpha=0.3, seed=1)
    model = SplitModel(get_config("resnet8"))
    results = {}
    for name, kw in VARIANTS.items():
        ecfg = EngineConfig(mode="s2fl", rounds=ROUNDS, clients_per_round=5,
                            batch_size=32, lr=0.05, group_size=2, seed=1,
                            **kw)
        eng = S2FLEngine(model, fed, ecfg)
        with Timer() as t:
            eng.run()
            res = eng.evaluate(test)
        results[name] = (res["acc"], eng.clock)
        emit(f"fig8.s2fl+{name}", t.us,
             f"acc={res['acc']:.4f};sim_clock={eng.clock:.1f}")
    # +M must not be slower than +R on the simulated clock
    emit("fig8.check", 0.0,
         f"clock_M_vs_R={results['M'][1] / results['R'][1]:.2f}")


if __name__ == "__main__":
    run()
