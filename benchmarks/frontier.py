"""Accuracy x wall-clock frontier: resource-aware vs blind forecasting.

Three CONTENDED regimes (the only ones where the forecasts disagree —
on an uncontended fabric both price the same Eq.-1 physics):

  server_bound : 1 server backward slot, free links. The blind forecast
                 prices compute + transfer but never the FIFO queue, so
                 it happily picks splits with heavy server portions; the
                 resource-aware forecast charges ``depth x duration /
                 slots`` and steers toward client-heavy splits that
                 drain the bottleneck.
  uplink_jam   : shared ingress (one Table-1 server link for the whole
                 cohort), 2 slots. Blind divides the link by cohort
                 LOAD for every leg — including the model dispatch/
                 collect legs that do not ride the fluid link in the
                 simulator — so it overcharges model-heavy splits;
                 aware prices the fair share + live backlog of exactly
                 the legs that contend.
  duplex_gate  : ingress + egress contended, 2 slots, re-dispatch gated
                 on the device's own draining download. Blind knows
                 nothing of the gate; aware starts its forecast at
                 ``busy_until(cid)`` and adds both directions' backlog.
  downlink_jam : shared egress only (one Table-1 server link for every
                 dispatch/collect leg), 2 slots, free ingress. Blind
                 halves the *uplink* by LOAD but treats the download as
                 private; aware prices the marginal egress backlog
                 (``behind x down / C_dn`` Pigouvian term) and steers
                 away from splits with heavy model-dispatch legs.

Each regime drives IDENTICAL participant draws through two policies
(MinTime scheduler both — only the forecast differs: ``predictive``
mean-rate vs ``resource_aware`` ResourceView) plus a third frontier
point, the joint split x batch-fraction tuner (JointKnobScheduler),
which trades sample mass for clock. Reported per regime:

  makespan      simulated clock after flush (deterministic, CI-compared)
  sample mass   committed samples (accuracy-progress proxy; blind and
                aware commit identical mass, the joint point may spend
                less)

Acceptance (ISSUE 9): weak domination — aware never slower than blind
on any contended regime, and >=1.2x faster on at least one.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, emit


def _policies(plan):
    """(name, scheduler-factory, driver-kwargs) per frontier point."""
    from repro.core.scheduler import JointKnobScheduler, MinTimeScheduler
    return (
        ("blind", lambda: MinTimeScheduler(plan), {"predictive": True}),
        ("aware", lambda: MinTimeScheduler(plan),
         {"resource_aware": True}),
        ("joint", lambda: JointKnobScheduler(plan),
         {"resource_aware": True}),
    )


def _run_regime(arch, regime_kw, n_devices, per_round, rounds, seed=0,
                composition=None):
    """Drive every policy over the SAME participant draws under one
    resource regime. Returns {policy: (makespan, sample_mass)}."""
    from repro.comm import CommChannel
    from repro.configs import get_config
    from repro.core.driver import AnalyticCost, RoundDriver
    from repro.core.simulation import make_device_grid
    from repro.core.split import default_plan
    from repro.models import SplitModel
    from repro.utils.flops import split_costs

    model = SplitModel(get_config(arch))
    plan = default_plan(model.n_units, k=3)
    costs = {s: split_costs(model, s) for s in plan.split_points}
    devices = make_device_grid(n_devices, seed=seed,
                               composition=composition)
    p = 128
    out = {}
    for name, mk_sched, drv_kw in _policies(plan):
        ch = CommChannel(uplink_capacity=regime_kw.get("uplink", 0.0),
                         downlink_capacity=regime_kw.get("downlink", 0.0))
        sched = mk_sched()
        drv = RoundDriver(
            sched, AnalyticCost(ch, costs, p=p), devices,
            mode="semi_async", pipeline=True,
            staleness_cap=regime_kw.get("staleness_cap", 1),
            server_concurrency=regime_kw.get("server_slots", 0),
            gate_redispatch=regime_kw.get("gate", False), **drv_kw)
        rng = np.random.default_rng(seed)
        mass = 0.0
        for _ in range(rounds):
            part = rng.choice(devices, size=per_round, replace=False)
            drv.run_round(part)
            fracs = getattr(sched, "selected_fracs", None) or {}
            mass += sum(p * fracs.get(d.cid, 1.0) for d in part)
        drv.flush()
        out[name] = (drv.clock, mass)
    return out


# regime -> (resource knobs, device mix). The server-bound regime runs
# a FAST-client mix (5:3:2) so the FIFO slot is the true bottleneck —
# on a straggler mix the low devices' client compute masks whatever the
# queue does; the link regimes keep the paper's straggler-heavy 2:3:5.
REGIMES = (
    ("server_bound", {"server_slots": 1},
     {"high": 5, "mid": 3, "low": 2}),
    ("uplink_jam", {"server_slots": 2, "uplink": "SERVER_RATE"},
     {"high": 2, "mid": 3, "low": 5}),
    ("duplex_gate", {"server_slots": 2, "uplink": "SERVER_RATE",
                     "downlink": "SERVER_RATE", "gate": True},
     {"high": 2, "mid": 3, "low": 5}),
    ("downlink_jam", {"server_slots": 2, "downlink": "SERVER_RATE"},
     {"high": 2, "mid": 3, "low": 5}),
)


def run(quick: bool = False):
    from repro.core.simulation import SERVER_RATE
    rounds = 8 if quick else 16
    n_dev = 30 if quick else 60

    speedups = {}
    for rname, kw, comp in REGIMES:
        kw = {k: (SERVER_RATE if v == "SERVER_RATE" else v)
              for k, v in kw.items()}
        with Timer() as t:
            res = _run_regime("vgg16", kw, n_devices=n_dev,
                              per_round=10, rounds=rounds,
                              composition=comp)
        (blind, m_blind), (aware, m_aware) = res["blind"], res["aware"]
        joint, m_joint = res["joint"]
        sp = blind / aware
        speedups[rname] = sp
        # apples-to-apples: blind and aware commit identical sample mass
        # (same draws, full batches) — the frontier compares pure clock
        assert m_blind == m_aware, (m_blind, m_aware)
        emit(f"frontier.{rname}", t.us,
             f"blind_makespan={blind:.2f};aware_makespan={aware:.2f};"
             f"speedup={sp:.2f}x;joint_makespan={joint:.2f};"
             f"joint_mass_frac={m_joint / m_blind:.3f}")

    # ISSUE-9 acceptance: weak domination on the contended regimes —
    # never slower (tiny fp slack), >=1.2x faster somewhere
    for rname, sp in speedups.items():
        assert sp >= 0.9995, f"aware slower than blind on {rname}: {sp}"
    assert max(speedups.values()) >= 1.2, speedups


if __name__ == "__main__":
    import argparse

    from benchmarks.common import write_json

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny-scale smoke (CI)")
    ap.add_argument("--out", default="",
                    help="write rows as JSON (for compare.py)")
    a = ap.parse_args()
    run(quick=a.quick)
    if a.out:
        write_json(a.out)
