"""Render the EXPERIMENTS.md §Roofline table from the dry-run JSON(s).

  PYTHONPATH=src python -m benchmarks.roofline_report \
      dryrun_single_pod.json [dryrun_multi_pod.json]
"""
from __future__ import annotations

import json
import sys


def fmt(r: dict) -> str:
    if r.get("skipped"):
        return (f"| {r['arch']} | {r['shape']} | — | — | — | — | skip "
                f"({r['reason'][:30]}) | — | — |")
    if "error" in r:
        return f"| {r['arch']} | {r['shape']} | ERROR {r['error'][:60]} |"
    peak = r.get("peak_bytes") or 0
    return ("| {arch} | {shape} | {tc:.3f} | {tm:.3f} | {tl:.3f} | "
            "**{dom}** | {uf:.2f} | {pk:.1f} | {cs:.0f} |".format(
                arch=r["arch"], shape=r["shape"], tc=r["t_compute_s"],
                tm=r["t_memory_s"], tl=r["t_collective_s"],
                dom=r["dominant"], uf=r["useful_ratio"], pk=peak / 1e9,
                cs=r.get("compile_s", 0)))


def main(paths):
    for p in paths:
        with open(p) as f:
            recs = json.load(f)
        chips = next((r.get("chips") for r in recs if "chips" in r), "?")
        print(f"\n### {p} ({chips} chips)\n")
        print("| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | "
              "dominant | useful | peak GB/chip | compile s |")
        print("|---|---|---|---|---|---|---|---|---|")
        for r in recs:
            print(fmt(r))
        live = [r for r in recs if "dominant" in r]
        doms = {}
        for r in live:
            doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
        print(f"\n{len(live)} live pairs; dominant-term histogram: {doms}")
        worst = sorted(live, key=lambda r: r["useful_ratio"])[:3]
        coll = sorted(live, key=lambda r: -r["t_collective_s"])[:3]
        print("lowest useful:", [(r["arch"], r["shape"],
                                  round(r["useful_ratio"], 2))
                                 for r in worst])
        print("most collective-bound:",
              [(r["arch"], r["shape"], round(r["t_collective_s"], 2))
               for r in coll])


if __name__ == "__main__":
    main(sys.argv[1:] or ["dryrun_single_pod.json"])
