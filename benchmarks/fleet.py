"""Million-device fleet sweep: O(active cohort) per-round cost.

Sweeps the population P across >= 3 decades (10^2 -> 10^5 in --quick,
10^6 in the full run) while the active cohort stays FIXED, driving the
semi-async pipelined driver with hierarchical aggregation, churn and
diurnal availability over batched `core/fleet.py` population tables.

Asserted invariants (the ISSUE-10 acceptance):

  flat per-round cost   median per-round wall time may not grow with P
                        (max/min ratio bounded across the sweep — the
                        driver only ever touches the sampled cohort)
  bounded memory        population tables stay O(P) bytes at <= 64
                        B/device, and the driver materializes Device
                        objects only for sampled cids (<= rounds x
                        cohort, never P)
  small-N equivalence   the fleet driver reproduces the object driver's
                        clock to <= 1e-6 (bit-exact in practice) on
                        sync AND semi-async pipelined fp32 paths when
                        both observe the same warm-up set
  exactly-once          n_dispatched == n_committed + n_abandoned under
                        churn at every population size

Emitted rows: ``fleet.P<population>`` with the median per-round wall
time and a deterministic ``fleet_makespan`` (simulated clock — gated by
benchmarks/compare.py against benchmarks/baselines/BENCH_fleet.json),
plus an ungated ``fleet.equiv`` row with the object-vs-fleet clock
diff.
"""
from __future__ import annotations

import statistics
import time

from benchmarks.common import Timer, emit

COHORT = 64         # active devices per round — constant across P
CLUSTERS = 16
CLUSTER_QUORUM = 0.8


def _vgg_costs():
    from repro.configs import get_config
    from repro.core.split import default_plan
    from repro.models import SplitModel
    from repro.utils.flops import split_costs

    model = SplitModel(get_config("vgg16"))
    plan = default_plan(model.n_units, k=3)
    return plan, {s: split_costs(model, s) for s in plan.split_points}


def _drive_fleet(population, rounds, plan, costs, seed=0):
    """One fleet run: churn + diurnal availability + hierarchical
    aggregation. Returns (median per-round wall us, driver)."""
    from repro.comm import CommChannel
    from repro.core.driver import AnalyticCost, RoundDriver
    from repro.core.fleet import Fleet
    from repro.core.scheduler import MinTimeScheduler

    fleet = Fleet.table1(population, seed=seed,
                        clusters=CLUSTERS,
                        diurnal_period=24, diurnal_duty=0.9,
                        churn_kill_prob=0.01, churn_rejoin_prob=0.5)
    ch = CommChannel(codec="fp32", latency=0.01,
                     uplink_capacity=2e7, downlink_capacity=2e7)
    drv = RoundDriver(MinTimeScheduler(plan), AnalyticCost(ch, costs, p=64),
                      [], fleet=fleet, mode="semi_async", pipeline=True,
                      quorum=0.6, staleness_cap=2,
                      cluster_quorum=CLUSTER_QUORUM)
    per_round = []
    for r in range(rounds):
        t0 = time.perf_counter()
        cohort = fleet.sample_cohort(r, COHORT)
        drv.run_round(cohort)
        per_round.append((time.perf_counter() - t0) * 1e6)
    drv.flush()
    assert drv.n_dispatched == drv.n_committed + drv.n_abandoned, (
        drv.n_dispatched, drv.n_committed, drv.n_abandoned)
    # the object-materialization bound: only sampled cids ever become
    # Python Devices — the driver must never walk the population
    assert len(drv._dev_by_id) <= rounds * COHORT, (
        len(drv._dev_by_id), population)
    assert fleet.nbytes <= 64 * population + 4096, fleet.nbytes
    return statistics.median(per_round), drv


def _small_n_equivalence(plan, costs):
    """Fleet driver == object driver at small N: same cohorts, same
    warm-up set, fp32 — the sync clock must match bit-for-bit (<= 1e-6
    asserted; equality expected) and so must the pipelined one."""
    from repro.comm import CommChannel
    from repro.core.driver import AnalyticCost, RoundDriver
    from repro.core.fleet import Fleet
    from repro.core.scheduler import MinTimeScheduler
    from repro.core.simulation import make_device_grid

    P, rounds, cohort = 48, 8, 12
    worst = 0.0
    for mode, pipeline in (("sync", False), ("semi_async", True)):
        sampler = Fleet.table1(P, seed=3)
        cohorts = [sampler.sample_cohort(r, cohort) for r in range(rounds)]

        def mk(kind):
            ch = CommChannel(codec="fp32", latency=0.01,
                             uplink_capacity=2e7, downlink_capacity=2e7)
            cost = AnalyticCost(ch, costs, p=32)
            if kind == "obj":
                devs = make_device_grid(P, seed=3)
                drv = RoundDriver(MinTimeScheduler(plan), cost, devs,
                                  mode=mode, pipeline=pipeline,
                                  quorum=0.5, staleness_cap=2)
                return drv, lambda r: [devs[c] for c in cohorts[r]]
            fl = Fleet.table1(P, seed=3)
            drv = RoundDriver(MinTimeScheduler(plan), cost, [], fleet=fl,
                              mode=mode, pipeline=pipeline,
                              quorum=0.5, staleness_cap=2,
                              warmup_devices=fl.devices_for(range(P)))
            return drv, lambda r: cohorts[r]

        d_obj, part_obj = mk("obj")
        d_flt, part_flt = mk("fleet")
        for r in range(rounds):
            a = d_obj.run_round(part_obj(r))
            b = d_flt.run_round(part_flt(r))
            assert a.committed == b.committed, (mode, r)
        d_obj.flush()
        d_flt.flush()
        diff = abs(d_obj.clock - d_flt.clock)
        assert diff <= 1e-6, (mode, pipeline, d_obj.clock, d_flt.clock)
        assert d_obj.comm == d_flt.comm
        worst = max(worst, diff)
    return worst


def run(quick: bool = False):
    plan, costs = _vgg_costs()
    rounds = 6 if quick else 10
    pops = [100, 1_000, 10_000, 100_000]
    if not quick:
        pops.append(1_000_000)

    meds = {}
    for P in pops:
        with Timer() as t:
            med_us, drv = _drive_fleet(P, rounds, plan, costs)
        meds[P] = med_us
        emit(f"fleet.P{P}", med_us,
             f"fleet_makespan={drv.clock:.2f};"
             f"materialized={len(drv._dev_by_id)};"
             f"table_mb={drv._fleet.nbytes / 1e6:.1f};"
             f"total_us={t.us:.0f}")

    # per-round cost flat in P across >= 3 decades: generous 8x slack
    # absorbs timer noise, while an O(P) round loop would blow through
    # it by orders of magnitude (the sweep spans 3-4 decades)
    lo, hi = min(meds.values()), max(meds.values())
    assert hi <= 8.0 * lo + 2_000.0, meds

    with Timer() as t:
        diff = _small_n_equivalence(plan, costs)
    emit("fleet.equiv", t.us, f"max_clock_diff={diff:.2e}")


if __name__ == "__main__":
    import argparse

    from benchmarks.common import write_json

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny-scale smoke, populations to 1e5 (CI)")
    ap.add_argument("--out", default="",
                    help="write rows as JSON (for compare.py)")
    a = ap.parse_args()
    run(quick=a.quick)
    if a.out:
        write_json(a.out)
