"""Benchmark orchestrator — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--only fig3,table3,...]
  REPRO_BENCH_ROUNDS=40 ... python -m benchmarks.run --only table2

Default set keeps CPU wall-time tractable: the accuracy suites (table2 /
fig8) run at reduced rounds; scale up via REPRO_BENCH_ROUNDS.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = [
    ("fig3", "benchmarks.portions"),        # portion sizes/FLOPs
    ("table3", "benchmarks.time_comm"),     # time + comm overhead
    ("fig5-7", "benchmarks.sweeps"),        # device sweeps
    ("kernels", "benchmarks.kernels_bench"),
    ("roofline", "benchmarks.roofline"),
    ("table2", "benchmarks.accuracy"),      # accuracy (slow)
    ("fig8", "benchmarks.ablation"),        # ablation (slow)
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: "
                         + ",".join(k for k, _ in MODULES))
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for key, modname in MODULES:
        if only and key not in only:
            continue
        t0 = time.time()
        try:
            mod = __import__(modname, fromlist=["run"])
            mod.run()
            print(f"# {key} ({modname}) ok in {time.time() - t0:.0f}s",
                  file=sys.stderr)
        except Exception:
            failures += 1
            print(f"{key}.FAILED,0,{modname}")
            traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
