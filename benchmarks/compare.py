"""CI bench-regression gate: diff a benchmark run against its committed
baseline and FAIL the build when a machine-stable metric regresses.

Benchmark rows (``benchmarks/common.emit``) carry two kinds of numbers:

  us_per_call        raw wall time — machine/load dependent, NEVER gated
  derived metrics    ``key=value`` pairs inside the derived string —
                     the ratios and simulated clocks that are
                     deterministic for a fixed seed, and therefore
                     comparable across CI runners

Only two metric shapes are gated (everything else in a derived string
is informational):

  speedup=1.42x      higher is better (fused-vs-sequential cohort
                     ratios, aware-vs-blind frontier ratios)
  *makespan=363.47   lower is better (frontier simulated clocks)

A metric regresses when it is worse than its baseline by more than
``--tolerance`` (default 20%, the slack for jit/thread jitter in the
speedup ratios; the simulated makespans are bit-deterministic and only
move when the physics or the policy changes). Rows present in the
baseline but missing from the run fail the gate — a benchmark that
silently stopped running is a regression too. New rows are ignored
(they gate once they land in the baseline).

    python benchmarks/compare.py --baseline benchmarks/baselines/B.json \
        --current bench-artifacts/B.json [--tolerance 0.2]

``--update-baseline`` is the escape hatch for intentional perf changes:
it rewrites the baseline file with the current rows (commit the diff and
say why in the PR).
"""
from __future__ import annotations

import argparse
import json
import re
import shutil
import sys

_SPEEDUP = re.compile(r"(?:^|[;\s])(speedup)=([0-9.]+)x")
_MAKESPAN = re.compile(r"([A-Za-z0-9_.]*makespan)=([0-9.]+)")


def metrics_of(derived: str) -> dict:
    """{key: (value, higher_is_better)} for the gated metrics of one
    row's derived string."""
    out = {}
    for m in _SPEEDUP.finditer(derived):
        out[m.group(1)] = (float(m.group(2)), True)
    for m in _MAKESPAN.finditer(derived):
        out[m.group(1)] = (float(m.group(2)), False)
    return out


def load_rows(path: str) -> dict:
    with open(path) as f:
        rows = json.load(f)
    return {r["name"]: r.get("derived", "") for r in rows}


def compare(baseline: dict, current: dict, tolerance: float) -> list:
    """-> list of human-readable failure strings (empty = gate passes)."""
    fails = []
    for name, b_derived in sorted(baseline.items()):
        base = metrics_of(b_derived)
        if not base:
            continue
        if name not in current:
            fails.append(f"{name}: row missing from current run")
            continue
        cur = metrics_of(current[name])
        for key, (b, higher_better) in sorted(base.items()):
            if key not in cur:
                fails.append(f"{name}: metric {key} missing "
                             f"(baseline {b:g})")
                continue
            c = cur[key][0]
            if higher_better:
                bad = c < b * (1.0 - tolerance)
                arrow = f"{b:g} -> {c:g} (floor {b * (1 - tolerance):g})"
            else:
                bad = c > b * (1.0 + tolerance)
                arrow = f"{b:g} -> {c:g} (ceil {b * (1 + tolerance):g})"
            if bad:
                fails.append(f"{name}: {key} regressed {arrow}")
    return fails


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", required=True,
                    help="committed baseline JSON "
                         "(benchmarks/baselines/)")
    ap.add_argument("--current", required=True,
                    help="this run's JSON (write_json output)")
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="allowed fractional regression (default 0.2)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="overwrite the baseline with the current rows "
                         "instead of gating")
    a = ap.parse_args(argv)

    if a.update_baseline:
        shutil.copyfile(a.current, a.baseline)
        print(f"baseline updated: {a.current} -> {a.baseline}")
        return 0

    fails = compare(load_rows(a.baseline), load_rows(a.current),
                    a.tolerance)
    if fails:
        print(f"BENCH REGRESSION vs {a.baseline} "
              f"(tolerance {a.tolerance:.0%}):")
        for f in fails:
            print(f"  {f}")
        print("intentional? rerun with --update-baseline and commit "
              "the new baseline")
        return 1
    n = len([1 for d in load_rows(a.baseline).values() if metrics_of(d)])
    print(f"bench gate OK: {n} gated rows within "
          f"{a.tolerance:.0%} of {a.baseline}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
