"""Figure 3 — sizes and FLOPs of model portions (Wc_1 < Wc_2 < Wc_3 < W)
for the paper's three models, via the thop-equivalent accounting in
repro.utils.flops."""
from __future__ import annotations

from benchmarks.common import Timer, emit
from repro.configs import get_config
from repro.core.split import default_plan
from repro.models import SplitModel
from repro.utils.flops import client_portion_size, full_size, split_costs


def run():
    for arch in ("resnet8", "vgg16", "mobilenet"):
        model = SplitModel(get_config(arch))
        plan = default_plan(model.n_units, k=3)
        with Timer() as t:
            rows = []
            for i, s in enumerate(plan.split_points):
                c = split_costs(model, s)
                rows.append((f"Wc_{i + 1}", client_portion_size(model, s),
                             c["fc"]))
            rows.append(("W", full_size(model),
                         split_costs(model, 1)["f_full"]))
        for name, size, fl in rows:
            emit(f"fig3.{arch}.{name}", t.us / len(rows),
                 f"params={size:.3e};flops={fl:.3e}")
        # invariant from the paper: Wc_1 < Wc_2 < Wc_3 < W
        sizes = [r[1] for r in rows]
        assert all(a < b for a, b in zip(sizes, sizes[1:])), (arch, sizes)


if __name__ == "__main__":
    run()
