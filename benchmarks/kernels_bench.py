"""Kernel micro-benchmarks: wall time of the Pallas kernels (interpret
mode on CPU — correctness-representative, not perf-representative; real
perf comes from the dry-run roofline) vs their pure-jnp oracles."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Timer, emit

KEY = jax.random.PRNGKey(0)


def _bench(fn, *args, iters=3):
    out = fn(*args)
    jax.block_until_ready(out)
    with Timer() as t:
        for _ in range(iters):
            jax.block_until_ready(fn(*args))
    return t.us / iters


def run():
    from repro.kernels.flash_attention.kernel import flash_attention_fwd
    from repro.kernels.flash_attention.ref import attention_ref
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (8, 512, 64))
    k = jax.random.normal(ks[1], (4, 512, 64))
    v = jax.random.normal(ks[2], (4, 512, 64))
    us_k = _bench(jax.jit(lambda q, k, v: flash_attention_fwd(
        q, k, v, causal=True, groups=2, interpret=True)), q, k, v)
    us_r = _bench(jax.jit(lambda q, k, v: attention_ref(
        q, k, v, causal=True, groups=2)), q, k, v)
    emit("kern.flash_attn.8x512x64", us_k, f"ref_us={us_r:.0f}")

    from repro.kernels.ssd_scan.kernel import ssd_scan_pallas
    from repro.models.ssm import ssd_scan_ref
    b, s, h, p, n = 2, 512, 4, 64, 32
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    us_k = _bench(jax.jit(lambda *a: ssd_scan_pallas(
        *a, chunk=128, interpret=True)), x, dt, A, B, C)
    us_r = _bench(jax.jit(lambda *a: ssd_scan_ref(*a, chunk=128)),
                  x, dt, A, B, C)
    emit("kern.ssd_scan.2x512x4x64", us_k, f"ref_us={us_r:.0f}")

    from repro.kernels.moe_gmm.kernel import moe_gmm
    from repro.kernels.moe_gmm.ref import moe_gmm_ref
    ks = jax.random.split(KEY, 4)
    xg = jax.random.normal(ks[0], (8, 128, 256)) * 0.5
    wg = jax.random.normal(ks[1], (8, 256, 512)) * 0.05
    wu = jax.random.normal(ks[2], (8, 256, 512)) * 0.05
    wd = jax.random.normal(ks[3], (8, 512, 256)) * 0.05
    us_k = _bench(jax.jit(lambda *a: moe_gmm(*a, interpret=True)),
                  xg, wg, wu, wd)
    us_r = _bench(jax.jit(moe_gmm_ref), xg, wg, wu, wd)
    emit("kern.moe_gmm.8x128x256x512", us_k, f"ref_us={us_r:.0f}")


if __name__ == "__main__":
    run()
