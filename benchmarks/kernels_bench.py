"""Kernel micro-benchmarks: wall time of the Pallas kernels (interpret
mode on CPU — correctness-representative, not perf-representative; real
perf comes from the dry-run roofline) vs their pure-jnp oracles, plus
the batched cohort-compression hot path vs the sequential per-device
codec loop it replaces.

Every row carries the oracle/sequential comparator in the derived
column; the fused-vs-sequential rows also carry an explicit ``speedup``
so the CI artifact (``--out`` JSON) makes perf-ordering regressions
diffable per PR. The fused rows time the REAL dispatch path — backend
selection included (jnp oracle off-TPU, compiled Pallas on TPU) and the
``jnp.stack`` cohort assembly inside the timed region, since that is
the cost the engine actually pays per direction."""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import Timer, emit

KEY = jax.random.PRNGKey(0)
ITERS = 10               # default; override with --iters
WARMUP = 2


def _bench(fn, *args, iters=None):
    iters = ITERS if iters is None else iters
    for _ in range(WARMUP):
        jax.block_until_ready(fn(*args))
    with Timer() as t:
        for _ in range(iters):
            jax.block_until_ready(fn(*args))
    return t.us / iters


def _speedup(us_base, us_new) -> str:
    return f"speedup={us_base / us_new:.2f}x"


def run_model_kernels():
    from repro.kernels.flash_attention.kernel import flash_attention_fwd
    from repro.kernels.flash_attention.ref import attention_ref
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (8, 512, 64))
    k = jax.random.normal(ks[1], (4, 512, 64))
    v = jax.random.normal(ks[2], (4, 512, 64))
    us_k = _bench(jax.jit(lambda q, k, v: flash_attention_fwd(
        q, k, v, causal=True, groups=2, interpret=True)), q, k, v)
    us_r = _bench(jax.jit(lambda q, k, v: attention_ref(
        q, k, v, causal=True, groups=2)), q, k, v)
    emit("kern.flash_attn.8x512x64", us_k, f"ref_us={us_r:.0f}")

    from repro.kernels.ssd_scan.kernel import ssd_scan_pallas
    from repro.models.ssm import ssd_scan_ref
    b, s, h, p, n = 2, 512, 4, 64, 32
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    us_k = _bench(jax.jit(lambda *a: ssd_scan_pallas(
        *a, chunk=128, interpret=True)), x, dt, A, B, C)
    us_r = _bench(jax.jit(lambda *a: ssd_scan_ref(*a, chunk=128)),
                  x, dt, A, B, C)
    emit("kern.ssd_scan.2x512x4x64", us_k, f"ref_us={us_r:.0f}")

    from repro.kernels.moe_gmm.kernel import moe_gmm
    from repro.kernels.moe_gmm.ref import moe_gmm_ref
    ks = jax.random.split(KEY, 4)
    xg = jax.random.normal(ks[0], (8, 128, 256)) * 0.5
    wg = jax.random.normal(ks[1], (8, 256, 512)) * 0.05
    wu = jax.random.normal(ks[2], (8, 256, 512)) * 0.05
    wd = jax.random.normal(ks[3], (8, 512, 256)) * 0.05
    us_k = _bench(jax.jit(lambda *a: moe_gmm(*a, interpret=True)),
                  xg, wg, wu, wd)
    us_r = _bench(jax.jit(moe_gmm_ref), xg, wg, wu, wd)
    emit("kern.moe_gmm.8x128x256x512", us_k, f"ref_us={us_r:.0f}")


def run_comm_kernels():
    """The wire kernels: the int8 quantize/dequantize pair, the fused
    single-pass roundtrips, and the batched cohort call vs the
    per-device loop it replaces in the engine."""
    from repro.kernels.int8_quant.kernel import (int8_dequantize_pallas,
                                                 int8_quantize_pallas)
    from repro.kernels.int8_quant.ref import (int8_dequantize_ref,
                                              int8_quantize_ref)
    rows = jax.random.normal(KEY, (2048, 256)) * 2.0

    def pair_pallas(x):
        q, s, z = int8_quantize_pallas(x, interpret=True)
        return int8_dequantize_pallas(q, s, z, interpret=True)

    def pair_ref(x):
        q, s, z = int8_quantize_ref(x)
        return int8_dequantize_ref(q, s, z)

    us_k = _bench(jax.jit(pair_pallas), rows)
    us_r = _bench(jax.jit(pair_ref), rows)
    emit("kern.int8_pair.2048x256", us_k, f"ref_us={us_r:.0f}")

    # the fused single-kernel roundtrip vs the same two-kernel pair
    from repro.kernels.comm_fused.kernel import (int8_roundtrip_pallas,
                                                 sparse_combine_pallas)
    from repro.kernels.comm_fused.ref import (int8_roundtrip_ref,
                                              sparse_combine_ref)
    us_k = _bench(lambda x: int8_roundtrip_pallas(x, interpret=True),
                  rows)
    us_r = _bench(jax.jit(int8_roundtrip_ref), rows)
    emit("kern.fused_int8_rt.2048x256", us_k, f"ref_us={us_r:.0f}")

    d, n = 16, 16384
    y = jax.random.normal(KEY, (d, n))
    mask = (jax.random.uniform(jax.random.fold_in(KEY, 1), (d, n))
            < 0.1).astype(jnp.float32)
    us_k = _bench(lambda *a: sparse_combine_pallas(
        *a, 1.0, interpret=True), y, mask)
    us_r = _bench(jax.jit(lambda *a: sparse_combine_ref(*a, 1.0)),
                  y, mask)
    emit(f"kern.sparse_combine.{d}x{n}", us_k, f"ref_us={us_r:.0f}")


def run_cohort_vs_sequential():
    """The engine-level contest the fused path exists for: ONE batched
    (D, N) call per direction vs D per-device codec roundtrips. Both
    sides run their real dispatch (backend-selected kernel vs the
    per-device jnp chain); the fused side pays its jnp.stack cohort
    assembly inside the timed region."""
    from repro.comm.codecs import get_codec
    from repro.kernels.comm_fused import (fused_int8_roundtrip,
                                          fused_sparse_roundtrip)
    d, n = 16, 32768
    parts = [jax.random.normal(jax.random.fold_in(KEY, i), (n,))
             for i in range(d)]

    int8 = get_codec("int8")
    us_f = _bench(lambda: fused_int8_roundtrip(jnp.stack(parts), None)[0])
    us_s = _bench(lambda: [int8.roundtrip(p)[0] for p in parts])
    emit(f"comm.cohort_int8.{d}x{n}", us_f,
         f"seq_us={us_s:.0f} {_speedup(us_s, us_f)}")

    topk = get_codec("topk", topk_frac=0.1)
    k = max(1, -(-n // 10))
    us_f = _bench(lambda: fused_sparse_roundtrip(jnp.stack(parts), None,
                                                 k=k, scale=1.0)[0])
    us_s = _bench(lambda: [topk.roundtrip(p)[0] for p in parts])
    emit(f"comm.cohort_topk.{d}x{n}", us_f,
         f"seq_us={us_s:.0f} {_speedup(us_s, us_f)}")

    # error-feedback variant: residual add + update fused into the same
    # call vs the channel's separate add / subtract around each encode
    res = [jax.random.normal(jax.random.fold_in(KEY, 100 + i), (n,))
           * 0.1 for i in range(d)]

    def seq_ef():
        outs = []
        for p, r in zip(parts, res):
            y = p + r
            out, _ = int8.roundtrip(y)
            outs.append((out, y - out))
        return outs

    us_f = _bench(lambda: fused_int8_roundtrip(jnp.stack(parts),
                                               jnp.stack(res)))
    us_s = _bench(seq_ef)
    emit(f"comm.cohort_int8_ef.{d}x{n}", us_f,
         f"seq_us={us_s:.0f} {_speedup(us_s, us_f)}")


def run():
    run_model_kernels()
    run_comm_kernels()
    run_cohort_vs_sequential()


def main(argv=None):
    global ITERS
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--iters", type=int, default=ITERS,
                    help="timed iterations per row (after "
                         f"{WARMUP} warmup calls)")
    ap.add_argument("--out", default=None,
                    help="also dump every emitted row to this JSON "
                         "path (CI uploads it as BENCH_kernels.json)")
    args = ap.parse_args(argv)
    ITERS = args.iters
    run()
    if args.out:
        from benchmarks.common import write_json
        write_json(args.out)


if __name__ == "__main__":
    main()
