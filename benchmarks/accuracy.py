"""Table 2 / Figure 4 — test accuracy of FedAvg vs SFL vs S²FL under IID
and Dirichlet non-IID on synthetic CIFAR-shaped data.

CPU-scale reduction (documented in EXPERIMENTS.md): ResNet8 on synthetic
10-class data, fewer rounds/devices than the paper; the validated claim is
the ORDERING S²FL >= SFL ≈ FedAvg (paper: +16.5% max gain, S²FL best in
all 39 rows of Table 2), not absolute accuracies.

Env knobs: REPRO_BENCH_ROUNDS (default 20), REPRO_BENCH_CLIENTS (20).
"""
from __future__ import annotations

import os

from benchmarks.common import Timer, emit
from repro.configs import get_config
from repro.core.engine import EngineConfig, S2FLEngine
from repro.data.partition import federate
from repro.data.synthetic import make_image_dataset
from repro.models import SplitModel

ROUNDS = int(os.environ.get("REPRO_BENCH_ROUNDS", "20"))
CLIENTS = int(os.environ.get("REPRO_BENCH_CLIENTS", "20"))


def run_one(arch: str, alpha, mode: str, *, rounds=ROUNDS, seed=0):
    ds = make_image_dataset(3000, seed=seed)
    test = make_image_dataset(600, seed=seed + 77)
    fed = federate(ds, CLIENTS, alpha=alpha, seed=seed)
    model = SplitModel(get_config(arch))
    ecfg = EngineConfig(mode=mode, rounds=rounds, clients_per_round=5,
                        batch_size=32, group_size=2, lr=0.05, seed=seed)
    eng = S2FLEngine(model, fed, ecfg)
    eng.run()
    return eng.evaluate(test)


def run(archs=("resnet8",), alphas=(0.1, None)):
    for arch in archs:
        for alpha in alphas:
            tag = f"a{alpha}" if alpha else "iid"
            accs = {}
            for mode in ("fedavg", "sfl", "s2fl"):
                with Timer() as t:
                    res = run_one(arch, alpha, mode)
                accs[mode] = res["acc"]
                emit(f"table2.{arch}.{tag}.{mode}", t.us,
                     f"acc={res['acc']:.4f};loss={res['loss']:.4f}")
            emit(f"table2.{arch}.{tag}.gain", 0.0,
                 f"s2fl_minus_sfl={accs['s2fl'] - accs['sfl']:+.4f};"
                 f"s2fl_minus_fedavg={accs['s2fl'] - accs['fedavg']:+.4f}")


if __name__ == "__main__":
    run()
