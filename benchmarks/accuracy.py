"""Table 2 / Figure 4 — test accuracy of FedAvg vs SFL vs S²FL under IID
and Dirichlet non-IID on synthetic CIFAR-shaped data.

CPU-scale reduction (documented in EXPERIMENTS.md): ResNet8 on synthetic
10-class data, fewer rounds/devices than the paper; the validated claim is
the ORDERING S²FL >= SFL ≈ FedAvg (paper: +16.5% max gain, S²FL best in
all 39 rows of Table 2), not absolute accuracies.

Also (`frontier`): the codec x error-feedback accuracy-vs-bytes
frontier — the same S²FL run under each payload codec (fp32 / int8 /
topk) with feedback off and on, reporting final test accuracy against
the accumulated wire bytes, so a compression setting's accuracy cost is
visible next to its bandwidth win.

Env knobs: REPRO_BENCH_ROUNDS (default 20), REPRO_BENCH_CLIENTS (20).
``--quick`` shrinks everything to a CI smoke.
"""
from __future__ import annotations

import os

from benchmarks.common import Timer, emit
from repro.configs import get_config
from repro.configs.base import CommConfig
from repro.core.engine import EngineConfig, S2FLEngine
from repro.data.partition import federate
from repro.data.synthetic import make_image_dataset
from repro.models import SplitModel

ROUNDS = int(os.environ.get("REPRO_BENCH_ROUNDS", "20"))
CLIENTS = int(os.environ.get("REPRO_BENCH_CLIENTS", "20"))


def run_one(arch: str, alpha, mode: str, *, rounds=ROUNDS,
            clients=CLIENTS, n_train=3000, seed=0, comm=None):
    ds = make_image_dataset(n_train, seed=seed)
    test = make_image_dataset(max(200, n_train // 5), seed=seed + 77)
    fed = federate(ds, clients, alpha=alpha, seed=seed)
    model = SplitModel(get_config(arch))
    ecfg = EngineConfig(mode=mode, rounds=rounds, clients_per_round=5,
                        batch_size=32, group_size=2, lr=0.05, seed=seed,
                        comm=comm or CommConfig())
    eng = S2FLEngine(model, fed, ecfg)
    eng.run()
    res = eng.evaluate(test)
    res["comm"] = eng.comm
    res["clock"] = eng.clock
    return res


def run(archs=("resnet8",), alphas=(0.1, None), *, rounds=ROUNDS,
        clients=CLIENTS, n_train=3000):
    for arch in archs:
        for alpha in alphas:
            tag = f"a{alpha}" if alpha else "iid"
            accs = {}
            for mode in ("fedavg", "sfl", "s2fl"):
                with Timer() as t:
                    res = run_one(arch, alpha, mode, rounds=rounds,
                                  clients=clients, n_train=n_train)
                accs[mode] = res["acc"]
                emit(f"table2.{arch}.{tag}.{mode}", t.us,
                     f"acc={res['acc']:.4f};loss={res['loss']:.4f}")
            emit(f"table2.{arch}.{tag}.gain", 0.0,
                 f"s2fl_minus_sfl={accs['s2fl'] - accs['sfl']:+.4f};"
                 f"s2fl_minus_fedavg={accs['s2fl'] - accs['fedavg']:+.4f}")


def frontier(arch: str = "resnet8", *, rounds=ROUNDS, clients=CLIENTS,
             n_train=3000, alpha=0.3):
    """codec x error-feedback accuracy-vs-bytes frontier on the S²FL
    engine (real training: compression error flows through the loss).
    Returns {(codec, ef): (acc, comm_bytes)}; asserts the byte ordering
    topk < int8 < fp32 survives end-to-end metering."""
    out = {}
    for codec in ("fp32", "int8", "topk"):
        for ef in ((False,) if codec == "fp32" else (False, True)):
            comm = CommConfig(codec=codec, error_feedback=ef)
            with Timer() as t:
                res = run_one(arch, alpha, "s2fl", rounds=rounds,
                              clients=clients, n_train=n_train,
                              comm=comm)
            out[(codec, ef)] = (res["acc"], res["comm"])
            emit(f"frontier.{arch}.{codec}.{'ef' if ef else 'noef'}",
                 t.us,
                 f"acc={res['acc']:.4f};comm_bytes={res['comm']:.3e};"
                 f"sim_time_s={res['clock']:.1f}")
    assert out[("topk", False)][1] < out[("int8", False)][1] \
        < out[("fp32", False)][1], out
    return out


if __name__ == "__main__":
    import argparse

    from benchmarks.common import write_json

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny-scale smoke (CI): few rounds/clients, "
                         "table2 on one alpha + the codec frontier")
    ap.add_argument("--out", default="",
                    help="dump the emitted rows as JSON (CI artifact)")
    args = ap.parse_args()
    if args.quick:
        run(alphas=(0.3,), rounds=3, clients=6, n_train=600)
        frontier(rounds=3, clients=6, n_train=600)
    else:
        run()
        frontier()
    if args.out:
        write_json(args.out)
