"""Summarize a flight-level trace: per-window critical-path table and
component totals.

    # summarize a trace written by `repro.launch.train --trace-out`
    PYTHONPATH=src python -m benchmarks.trace_report trace.json

    # or generate a quick pipelined contended demo trace (model-free
    # synthetic costs — no XLA analysis, runs in seconds) and write it
    PYTHONPATH=src python -m benchmarks.trace_report --demo \\
        --out pipeline_trace.json

The trace file is the Chrome trace-event JSON (Perfetto-loadable) with
the full recorder dump embedded under its ``"s2fl"`` key — one artifact
serves both the viewer and this summarizer.
"""
from __future__ import annotations

import argparse

from repro.observe import (load_recorder, summarize, verify_reconstruction,
                           window_breakdown, write_chrome_trace)

# Synthetic per-split Eq.-1 quantities (the tests/test_driver.py regime:
# wc grows with the split, the cut-layer feature shrinks) — model-free
# so the demo needs no XLA cost analysis.
_PLAN_SPLITS = (1, 2, 4)
_COSTS = {1: dict(wc_size=2.0e5, feat_size=8.0e3, fc=6.0e8, fs=2.4e9),
          2: dict(wc_size=6.0e5, feat_size=4.0e3, fc=1.2e9, fs=1.8e9),
          4: dict(wc_size=1.8e6, feat_size=2.0e3, fc=2.4e9, fs=6.0e8)}


def demo_recorder(rounds: int = 10, n_devices: int = 12,
                  per_round: int = 5, seed: int = 0):
    """A recorded pipelined run against a finite Main Server: contended
    ingress AND egress, two server slots, gated re-dispatch,
    per-device-round latency draws — every subsystem the trace can
    see."""
    import numpy as np

    from repro.comm import CommChannel, StaticLink
    from repro.core.driver import AnalyticCost, RoundDriver
    from repro.core.scheduler import SlidingSplitScheduler
    from repro.core.simulation import SERVER_RATE, make_device_grid
    from repro.core.split import SplitPlan
    from repro.observe import Recorder

    devices = make_device_grid(n_devices, seed=seed)
    ch = CommChannel(codec="fp32", link=StaticLink(), latency=0.01,
                     latency_dist="uniform",
                     uplink_capacity=SERVER_RATE,
                     downlink_capacity=SERVER_RATE)
    rec = Recorder()
    drv = RoundDriver(
        SlidingSplitScheduler(SplitPlan(n_units=8,
                                        split_points=_PLAN_SPLITS)),
        AnalyticCost(ch, _COSTS, p=64), devices, mode="semi_async",
        pipeline=True, server_concurrency=2, gate_redispatch=True,
        recorder=rec)
    rng = np.random.default_rng(seed)
    for _ in range(rounds):
        drv.run_round(rng.choice(devices, size=per_round, replace=False))
    drv.flush()
    return rec


def report(rec):
    err = verify_reconstruction(rec)
    rows = window_breakdown(rec)
    s = summarize(rec)
    print(f"{'win':>4} {'kind':<6} {'makespan':>10} {'critical':>8}  "
          f"decomposition")
    for row in rows:
        comp = "  ".join(f"{k}={v:.3f}"
                         for k, v in sorted(row["components"].items())
                         if abs(v) > 1e-12)
        cid = row["critical_cid"]
        print(f"{row['round']:>4} {row['kind']:<6} "
              f"{row['makespan']:>10.4f} "
              f"{('c' + str(cid)) if cid is not None else '-':>8}  "
              f"{comp}")
    print(f"\ntotal makespan {s['total_makespan']:.4f}s over "
          f"{s['windows']} windows "
          f"(max reconstruction err {err:.2e})")
    print("component fractions:",
          "  ".join(f"{k}={v:.3f}"
                    for k, v in sorted(s["fractions"].items())))
    if s["top_straggler"] is not None:
        print(f"top straggler: device {s['top_straggler']} "
              f"(critical in {s['stragglers'][s['top_straggler']]} "
              f"windows, {s['straggler_time'][s['top_straggler']]:.3f}s)")
    return s


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="critical-path summary of a flight-level trace")
    ap.add_argument("trace", nargs="?", default=None,
                    help="trace JSON written by --trace-out / --out")
    ap.add_argument("--demo", action="store_true",
                    help="generate and summarize a quick pipelined "
                         "contended demo run (synthetic costs)")
    ap.add_argument("--out", default=None,
                    help="also write the (demo) trace JSON here")
    args = ap.parse_args(argv)
    if args.demo:
        rec = demo_recorder()
    elif args.trace:
        rec = load_recorder(args.trace)
    else:
        ap.error("give a trace file or --demo")
    if args.out:
        write_chrome_trace(rec, args.out)
        print(f"trace written to {args.out}")
    report(rec)


if __name__ == "__main__":
    main()
