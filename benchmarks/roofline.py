"""Roofline benchmark — reads the dry-run matrix JSON (produced by
``python -m repro.launch.dryrun --all --json dryrun_single_pod.json``) and
emits the three roofline terms per (arch × shape). If the JSON is missing,
computes a single fresh pair (internlm2-1.8b × train_4k) inline.

The full analysis narrative lives in EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import json
import os

from benchmarks.common import Timer, emit

JSON_PATHS = ("dryrun_single_pod.json", "/root/repo/dryrun_single_pod.json")


def run():
    recs = None
    for p in JSON_PATHS:
        if os.path.exists(p):
            with open(p) as f:
                recs = json.load(f)
            break
    if recs is None:
        from repro.launch.dryrun import dryrun_one
        with Timer() as t:
            recs = [dryrun_one("internlm2-1.8b", "train_4k",
                               verbose=False)]
    for r in recs:
        if r.get("skipped"):
            emit(f"roofline.{r['arch']}.{r['shape']}", 0.0, "skipped")
            continue
        if "error" in r:
            emit(f"roofline.{r['arch']}.{r['shape']}", 0.0,
                 f"ERROR={r['error'][:80]}")
            continue
        emit(f"roofline.{r['arch']}.{r['shape']}",
             max(r["t_compute_s"], r["t_memory_s"],
                 r["t_collective_s"]) * 1e6,
             f"compute_s={r['t_compute_s']:.4f};"
             f"memory_s={r['t_memory_s']:.4f};"
             f"collective_s={r['t_collective_s']:.4f};"
             f"dominant={r['dominant']};useful={r['useful_ratio']:.2f}")


if __name__ == "__main__":
    run()
