"""Table 3 — training time and communication overhead to reach target
accuracy, FedAvg vs SFL vs S²FL on VGG16 (Eq.-1 simulated clock, Table-1
device grid — faithful to the paper's methodology; the 'accuracy' axis is
replaced by a fixed number of post-warmup rounds on CPU, since the clock
and comm per round are the quantities Eq. 1 defines).

All methods run through the shared ``RoundDriver`` on the channel byte
path (comm is wire BYTES, fp32 analytic payloads — the legacy
element-based helpers in core/simulation.py are deprecated). Reported:
per-round wall time + comm for each method and the S²FL/SFL and
S²FL/FedAvg speedups (the paper reports 3.54x time and 2.57x comm on
VGG16 at a=0.5), plus the sync vs semi_async vs phase-pipelined round
clock of the S²FL schedule (the pipeline commits a group at the end of
its server compute so uploads/backwards/downloads overlap across
devices; a contended column prices the shared Main-Server ingress).

Additionally (`sweep`): the repro.comm codec x link grid — for every
payload codec (fp32 / bf16 / fp16 / int8 / topk / randk) and link model
(static Table-1 vs trace-driven fading), the accumulated wire bytes and
summed round time of an S²FL schedule, analytic Eq.-1 byte accounting
as in comm/README.md.

And (`ef_grid`): the codec x error-feedback grid on a METERED channel —
real tensors cross the wire, so the encode/decode paths and the
residual accumulators are exercised for real. Reports exact uplink
bytes per codec (asserted: topk < int8 < fp32) and the cumulative-sum
reconstruction error with feedback off vs on (feedback compensates
dropped mass across rounds, so the cumulative error must shrink for the
sparsifiers)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, emit
from repro.comm import CommChannel, LinkTrace, StaticLink
from repro.configs import get_config
from repro.core.driver import AnalyticCost, FedAvgCost, RoundDriver
from repro.core.scheduler import (FixedSplitScheduler, MinTimeScheduler,
                                  SlidingSplitScheduler)
from repro.core.simulation import SERVER_RATE, make_device_grid
from repro.core.split import default_plan
from repro.models import SplitModel
from repro.utils.flops import split_costs


def simulate(arch: str = "vgg16", *, n_devices: int = 100,
             per_round: int = 10, rounds: int = 30, p: int = 128,
             seed: int = 0):
    """FedAvg vs SFL vs S²FL (median + beyond-paper min-time) on the
    static Table-1 grid. Returns {method: (clock, comm_bytes)} plus the
    semi_async S²FL clock under 's2fl_async', the phase-pipelined clock
    under 's2fl_pipe', the pipelined clock with a contended Main-Server
    ingress (capacity = one Table-1 server uplink shared by the whole
    cohort, in-flight uploads carried across windows) under
    's2fl_pipe_contended', and the fully resource-constrained pipeline
    (duplex contention + 2 server backward slots + re-dispatch gating)
    under 's2fl_pipe_resourced'."""
    model = SplitModel(get_config(arch))
    plan = default_plan(model.n_units, k=3)
    costs = {s: split_costs(model, s) for s in plan.split_points}
    full = split_costs(model, plan.largest())
    devices = make_device_grid(n_devices, seed=seed)

    def make(name):
        if name == "fedavg":
            return RoundDriver(FixedSplitScheduler(plan),
                               FedAvgCost(full, p=p), devices)
        up_cap = SERVER_RATE if name in ("s2fl_pipe_contended",
                                         "s2fl_pipe_resourced") else 0.0
        dn_cap = SERVER_RATE if name == "s2fl_pipe_resourced" else 0.0
        cost = AnalyticCost(CommChannel(uplink_capacity=up_cap,
                                        downlink_capacity=dn_cap),
                            costs, p=p)
        if name == "sfl":
            return RoundDriver(FixedSplitScheduler(plan), cost, devices)
        if name == "s2fl_mintime":
            return RoundDriver(MinTimeScheduler(plan), cost, devices)
        if name == "s2fl_async":
            return RoundDriver(SlidingSplitScheduler(plan), cost, devices,
                               mode="semi_async", staleness_cap=1)
        if name in ("s2fl_pipe", "s2fl_pipe_contended",
                    "s2fl_pipe_resourced"):
            rsrc = name == "s2fl_pipe_resourced"
            return RoundDriver(SlidingSplitScheduler(plan), cost, devices,
                               mode="semi_async", staleness_cap=1,
                               pipeline=True,
                               server_concurrency=2 if rsrc else 0,
                               gate_redispatch=rsrc)
        return RoundDriver(SlidingSplitScheduler(plan), cost, devices)

    out = {}
    for name in ("fedavg", "sfl", "s2fl", "s2fl_mintime", "s2fl_async",
                 "s2fl_pipe", "s2fl_pipe_contended",
                 "s2fl_pipe_resourced"):
        drv = make(name)
        rng = np.random.default_rng(seed)
        for r in range(rounds):
            part = rng.choice(devices, size=per_round, replace=False)
            drv.run_round(part)
        # semi_async/pipeline: include the straggler tail and draining
        # downloads so every method's clock covers the same work
        drv.flush()
        out[name] = (drv.clock, drv.comm)
    return out


def simulate_comm(arch: str = "resnet8", *, codec: str = "fp32",
                  link=None, n_devices: int = 30, per_round: int = 10,
                  rounds: int = 20, p: int = 128, seed: int = 0):
    """S²FL schedule under a payload codec + link model: accumulated wire
    bytes and summed Eq.-1 round time (analytic payloads — the channel's
    estimate_round_payload — so the sweep runs in milliseconds).
    Returns (sim_time_s, bytes, {cid: split} of the last round)."""
    model = SplitModel(get_config(arch))
    plan = default_plan(model.n_units, k=3)
    costs = {s: split_costs(model, s) for s in plan.split_points}
    devices = make_device_grid(n_devices, seed=seed)
    ch = CommChannel(codec=codec, link=link or StaticLink())
    drv = RoundDriver(SlidingSplitScheduler(plan),
                      AnalyticCost(ch, costs, p=p), devices)
    rng = np.random.default_rng(seed)
    rec = None
    for r in range(rounds):
        part = rng.choice(devices, size=per_round, replace=False)
        rec = drv.run_round(part)
    return drv.clock, drv.comm, (rec.splits if rec else {})


def sweep(arch: str = "resnet8", *, rounds: int = 20):
    """codec x link grid -> per-cell bytes + round-time columns."""
    links = {
        "static": StaticLink(),
        "trace": LinkTrace.fading(n_segments=8, period=600.0, lo=0.1,
                                  hi=1.0, seed=3),
    }
    base = None
    for codec in ("fp32", "bf16", "fp16", "int8", "topk", "randk"):
        for lname, link in links.items():
            with Timer() as t:
                clock, nbytes, _ = simulate_comm(arch, codec=codec,
                                                 link=link, rounds=rounds)
            if codec == "fp32" and lname == "static":
                base = nbytes
            emit(f"comm_sweep.{arch}.{codec}.{lname}", t.us,
                 f"bytes={nbytes:.3e};sim_round_time_s={clock:.1f};"
                 f"bytes_vs_fp32={base / nbytes:.2f}x")


def ef_grid(*, rounds: int = 16, shape=(16, 512), seed: int = 7):
    """codec x error-feedback grid on a metered CommChannel: ``rounds``
    feature tensors per cell cross the uplink for real. Columns: exact
    uplink wire bytes (identical across the feedback axis — feedback
    changes WHAT is sent, not how much) and the cumulative-sum
    reconstruction error ||sum_t x_t - sum_t rx_t|| — the quantity the
    error-feedback accumulators drive down (for lossless fp32 both
    columns are ~0). Returns {(codec, ef): (bytes, cum_err)} and asserts
    the acceptance ordering topk uplink bytes < int8 < fp32."""
    import jax
    import jax.numpy as jnp

    from repro.comm import CommChannel

    out = {}
    for codec in ("fp32", "bf16", "fp16", "int8", "topk", "randk"):
        for ef in (False, True):
            ch = CommChannel(codec=codec, error_feedback=ef)
            sent = np.zeros(shape)
            got = np.zeros(shape)
            with Timer() as t:
                for r in range(rounds):
                    x = jax.random.normal(jax.random.PRNGKey(
                        seed * 1000 + r), shape, jnp.float32)
                    rx = ch.uplink_features(0, x)
                    sent += np.asarray(x, np.float64)
                    got += np.asarray(rx, np.float64)
            err = float(np.linalg.norm(sent - got))
            out[(codec, ef)] = (ch.up_bytes, err)
            emit(f"ef_grid.{codec}.{'ef' if ef else 'noef'}", t.us,
                 f"uplink_bytes={ch.up_bytes:.3e};cum_sum_err={err:.3e};"
                 f"residual_mass={ch.residual_norm():.3e}")
    # acceptance: the sparse uplink is cheaper than int8, int8 than fp32
    assert out[("topk", False)][0] < out[("int8", False)][0] \
        < out[("fp32", False)][0], out
    # feedback compensates the dropped mass across rounds
    for codec in ("topk", "randk", "int8"):
        assert out[(codec, True)][1] < out[(codec, False)][1], codec
    # fp32 is lossless with or without feedback
    assert out[("fp32", True)][1] == out[("fp32", False)][1] == 0.0
    return out


def run(quick: bool = False):
    arches = ("vgg16", "resnet8") if quick else ("vgg16", "resnet8",
                                                 "mobilenet")
    rounds = 8 if quick else 30
    ef_grid(rounds=8 if quick else 16)
    for arch in arches:
        sweep(arch, rounds=8 if quick else 20)
    for arch in arches:
        with Timer() as t:
            res = simulate(arch, n_devices=30 if quick else 100,
                           rounds=rounds)
        for mode, (clock, comm) in res.items():
            emit(f"table3.{arch}.{mode}", t.us / 3,
                 f"sim_time_s={clock:.1f};comm_bytes={comm:.3e}")
        sp_t = res["sfl"][0] / res["s2fl"][0]
        sp_c = res["sfl"][1] / res["s2fl"][1]
        sp_ft = res["fedavg"][0] / res["s2fl"][0]
        sp_mt = res["sfl"][0] / res["s2fl_mintime"][0]
        sp_async = res["s2fl"][0] / res["s2fl_async"][0]
        sp_pipe = res["s2fl_async"][0] / res["s2fl_pipe"][0]
        sp_cont = res["s2fl_pipe_contended"][0] / res["s2fl_pipe"][0]
        sp_rsrc = res["s2fl_pipe_resourced"][0] / res["s2fl_pipe"][0]
        emit(f"table3.{arch}.speedup", t.us / 3,
             f"s2fl_vs_sfl_time={sp_t:.2f}x;s2fl_vs_sfl_comm={sp_c:.2f}x;"
             f"s2fl_vs_fedavg_time={sp_ft:.2f}x;"
             f"mintime_vs_sfl_time={sp_mt:.2f}x;"
             f"async_vs_sync_time={sp_async:.2f}x;"
             f"pipe_vs_seq_time={sp_pipe:.2f}x;"
             f"contention_slowdown={sp_cont:.2f}x;"
             f"resource_slowdown={sp_rsrc:.2f}x")
        if arch == "vgg16":
            # paper regime: S²FL strictly faster than SFL, SFL than FedAvg
            assert sp_t > 1.0 and sp_ft > 1.0
        # beyond-paper scheduler never loses to the paper's on wall clock
        assert res["s2fl_mintime"][0] <= res["s2fl"][0] * 1.02, arch
        # event-queue overlap can only help the clock (static Table-1
        # link: each window closes at or before the sync barrier), and
        # phase overlap can only help further
        assert sp_async >= 1.0, arch
        assert sp_pipe >= 1.0, arch
        # finite resources slow the clock when the SCHEDULE is held
        # fixed (the exact theorem lives in
        # tests/test_driver_properties.py on a FixedSplitScheduler);
        # here the sliding scheduler adapts to the stretched times it
        # observes, so allow it a small legitimate mitigation margin
        # rather than pinning >= 1.0. Ordering: resource-constrained
        # >= pipelined(contended) >= free-overlap.
        assert sp_cont >= 0.95, arch
        assert sp_rsrc >= sp_cont * 0.98, arch


if __name__ == "__main__":
    import argparse

    from benchmarks.common import write_json

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny-scale smoke (CI)")
    ap.add_argument("--out", default="",
                    help="dump the emitted rows as JSON (CI artifact)")
    args = ap.parse_args()
    run(quick=args.quick)
    if args.out:
        write_json(args.out)
