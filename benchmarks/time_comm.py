"""Table 3 — training time and communication overhead to reach target
accuracy, FedAvg vs SFL vs S²FL on VGG16 (Eq.-1 simulated clock, Table-1
device grid — faithful to the paper's methodology; the 'accuracy' axis is
replaced by a fixed number of post-warmup rounds on CPU, since the clock
and comm per round are the quantities Eq. 1 defines).

Reported: per-round wall time + comm for each method and the S²FL/SFL and
S²FL/FedAvg speedups (the paper reports 3.54x time and 2.57x comm on VGG16
at a=0.5).

Additionally (`sweep`): the repro.comm codec x link grid — for every
payload codec (fp32 / bf16 / fp16 / int8) and link model (static Table-1
vs trace-driven fading), the accumulated wire bytes and summed round
time of an S²FL schedule, analytic Eq.-1 byte accounting as in
comm/README.md."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, emit
from repro.comm import CommChannel, LinkTrace, StaticLink
from repro.configs import get_config
from repro.core.scheduler import SlidingSplitScheduler
from repro.core.simulation import (device_round_comm, device_round_time,
                                   fedavg_round_comm, fedavg_round_time,
                                   make_device_grid)
from repro.core.split import default_plan
from repro.models import SplitModel
from repro.utils.flops import split_costs


def simulate(arch: str = "vgg16", *, n_devices: int = 100,
             per_round: int = 10, rounds: int = 30, p: int = 128,
             seed: int = 0):
    model = SplitModel(get_config(arch))
    plan = default_plan(model.n_units, k=3)
    costs = {s: split_costs(model, s) for s in plan.split_points}
    full = split_costs(model, plan.largest())
    devices = make_device_grid(n_devices, seed=seed)
    rng = np.random.default_rng(seed)

    def t_of(dev, s):
        c = costs[s]
        return device_round_time(dev, wc_size=c["wc_size"],
                                 feat_size=c["feat_size"], p=p,
                                 fc=p * c["fc"], fs=p * c["fs"])

    out = {}
    # FedAvg
    clock = comm = 0.0
    for r in range(rounds):
        part = rng.choice(devices, size=per_round, replace=False)
        clock += max(fedavg_round_time(d, w_size=full["w_size"], p=p,
                                       f_full=full["f_full"]) for d in part)
        comm += per_round * fedavg_round_comm(w_size=full["w_size"])
    out["fedavg"] = (clock, comm)

    # SFL (fixed largest split)
    clock = comm = 0.0
    s3 = plan.largest()
    rng = np.random.default_rng(seed)
    for r in range(rounds):
        part = rng.choice(devices, size=per_round, replace=False)
        clock += max(t_of(d, s3) for d in part)
        comm += sum(device_round_comm(wc_size=costs[s3]["wc_size"],
                                      feat_size=costs[s3]["feat_size"], p=p)
                    for _ in part)
    out["sfl"] = (clock, comm)

    # S²FL (paper's median-matching sliding split) + the beyond-paper
    # min-time scheduler
    from repro.core.scheduler import MinTimeScheduler
    for name, sched in (("s2fl", SlidingSplitScheduler(plan)),
                        ("s2fl_mintime", MinTimeScheduler(plan))):
        clock = comm = 0.0
        rng = np.random.default_rng(seed)
        for r in range(rounds):
            part = rng.choice(devices, size=per_round, replace=False)
            if sched.warming_up:
                # §3.1: warm-up Wc goes to ALL devices -> full time table
                s = sched.warmup_split()
                for d in devices:
                    sched.observe(d.cid, s, t_of(d, s))
            sel = sched.select([d.cid for d in part])
            times = {}
            for d in part:
                s = sel[d.cid]
                times[d.cid] = t_of(d, s)
                comm += device_round_comm(wc_size=costs[s]["wc_size"],
                                          feat_size=costs[s]["feat_size"],
                                          p=p)
                sched.observe(d.cid, s, times[d.cid])
            clock += max(times.values())
            sched.end_round()
        out[name] = (clock, comm)
    return out


def simulate_comm(arch: str = "resnet8", *, codec: str = "fp32",
                  link=None, n_devices: int = 30, per_round: int = 10,
                  rounds: int = 20, p: int = 128, seed: int = 0):
    """S²FL schedule under a payload codec + link model: accumulated wire
    bytes and summed Eq.-1 round time (analytic payloads — the channel's
    estimate_round_payload — so the sweep runs in milliseconds).
    Returns (sim_time_s, bytes, {cid: split} of the last round)."""
    model = SplitModel(get_config(arch))
    plan = default_plan(model.n_units, k=3)
    costs = {s: split_costs(model, s) for s in plan.split_points}
    devices = make_device_grid(n_devices, seed=seed)
    ch = CommChannel(codec=codec, link=link or StaticLink())
    sched = SlidingSplitScheduler(plan)
    rng = np.random.default_rng(seed)

    def t_and_bytes(dev, s, clock):
        c = costs[s]
        return ch.analytic_round_time(
            dev, wc_size=c["wc_size"], n_values=p * c["feat_size"],
            fc=p * c["fc"], fs=p * c["fs"], t=clock)

    clock = comm = 0.0
    sel = {}
    for r in range(rounds):
        part = rng.choice(devices, size=per_round, replace=False)
        if sched.warming_up:
            s = sched.warmup_split()
            for d in devices:
                sched.observe(d.cid, s, t_and_bytes(d, s, clock)[0])
        sel = sched.select([d.cid for d in part])
        times = {}
        for d in part:
            t, nbytes = t_and_bytes(d, sel[d.cid], clock)
            times[d.cid] = t
            comm += nbytes
            sched.observe(d.cid, sel[d.cid], t)
        clock += max(times.values())
        sched.end_round()
    return clock, comm, sel


def sweep(arch: str = "resnet8"):
    """codec x link grid -> per-cell bytes + round-time columns."""
    links = {
        "static": StaticLink(),
        "trace": LinkTrace.fading(n_segments=8, period=600.0, lo=0.1,
                                  hi=1.0, seed=3),
    }
    base = None
    for codec in ("fp32", "bf16", "fp16", "int8"):
        for lname, link in links.items():
            with Timer() as t:
                clock, nbytes, _ = simulate_comm(arch, codec=codec,
                                                 link=link)
            if codec == "fp32" and lname == "static":
                base = nbytes
            emit(f"comm_sweep.{arch}.{codec}.{lname}", t.us,
                 f"bytes={nbytes:.3e};sim_round_time_s={clock:.1f};"
                 f"bytes_vs_fp32={base / nbytes:.2f}x")


def run():
    for arch in ("vgg16", "resnet8", "mobilenet"):
        sweep(arch)
    for arch in ("vgg16", "resnet8", "mobilenet"):
        with Timer() as t:
            res = simulate(arch)
        for mode, (clock, comm) in res.items():
            emit(f"table3.{arch}.{mode}", t.us / 3,
                 f"sim_time_s={clock:.1f};comm_elems={comm:.3e}")
        sp_t = res["sfl"][0] / res["s2fl"][0]
        sp_c = res["sfl"][1] / res["s2fl"][1]
        sp_ft = res["fedavg"][0] / res["s2fl"][0]
        sp_mt = res["sfl"][0] / res["s2fl_mintime"][0]
        emit(f"table3.{arch}.speedup", t.us / 3,
             f"s2fl_vs_sfl_time={sp_t:.2f}x;s2fl_vs_sfl_comm={sp_c:.2f}x;"
             f"s2fl_vs_fedavg_time={sp_ft:.2f}x;"
             f"mintime_vs_sfl_time={sp_mt:.2f}x")
        if arch == "vgg16":
            # paper regime: S²FL strictly faster than SFL, SFL than FedAvg
            assert sp_t > 1.0 and sp_ft > 1.0
        # beyond-paper scheduler never loses to the paper's on wall clock
        assert res["s2fl_mintime"][0] <= res["s2fl"][0] * 1.02, arch


if __name__ == "__main__":
    run()
