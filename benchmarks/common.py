"""Shared benchmark utilities: CSV emission per the harness contract
(`name,us_per_call,derived` rows) + experiment helpers.

``emit`` also records every row in-process so a benchmark driver can
dump the run as JSON (``write_json``) — CI uploads these as workflow
artifacts, making perf-ordering regressions diffable per PR."""
from __future__ import annotations

import json
import time

ROWS: list = []          # every emit() of this process, in order


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.3f},{derived}")
    ROWS.append({"name": name, "us_per_call": round(us_per_call, 3),
                 "derived": derived})


def write_json(path: str):
    """Dump every row emitted so far to ``path`` (CI artifact)."""
    with open(path, "w") as f:
        json.dump(ROWS, f, indent=1)
    print(f"# wrote {len(ROWS)} rows -> {path}")


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0

    @property
    def us(self):
        return self.dt * 1e6
