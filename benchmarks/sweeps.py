"""Figures 5/6/7 — configuration sweeps on the Eq.-1 simulated clock:
  fig5: number of participating devices x in {5, 10, 15, 20}
  fig6: device compositions High:Mid:Low = 5:3:2 vs 2:3:5
  fig7: client-set size |C| in {20, 50, 100} at fixed 0.1 sampling

The time/straggler effects are what Eq. 1 defines, so these sweeps report
the simulated per-round wall clock of SFL vs S²FL (the accuracy curves of
the figures are covered by benchmarks/accuracy.py at reduced scale)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, emit


def _sim(arch, n_devices, per_round, composition=None, rounds=20, seed=0):
    from repro.configs import get_config
    from repro.core.scheduler import SlidingSplitScheduler
    from repro.core.simulation import device_round_time, make_device_grid
    from repro.core.split import default_plan
    from repro.models import SplitModel
    from repro.utils.flops import split_costs

    model = SplitModel(get_config(arch))
    plan = default_plan(model.n_units, k=3)
    costs = {s: split_costs(model, s) for s in plan.split_points}
    devices = make_device_grid(n_devices, seed=seed,
                               composition=composition)
    rng = np.random.default_rng(seed)
    p = 128

    def t_of(dev, s):
        c = costs[s]
        return device_round_time(dev, wc_size=c["wc_size"],
                                 feat_size=c["feat_size"], p=p,
                                 fc=p * c["fc"], fs=p * c["fs"])

    sfl_clock = 0.0
    s3 = plan.largest()
    sched = SlidingSplitScheduler(plan)
    s2_clock = 0.0
    for r in range(rounds):
        part = rng.choice(devices, size=per_round, replace=False)
        sfl_clock += max(t_of(d, s3) for d in part)
        if sched.warming_up:
            s = sched.warmup_split()
            for d in devices:                # §3.1: warm-up hits all devices
                sched.observe(d.cid, s, t_of(d, s))
        sel = sched.select([d.cid for d in part])
        ts = {}
        for d in part:
            ts[d.cid] = t_of(d, sel[d.cid])
            sched.observe(d.cid, sel[d.cid], ts[d.cid])
        s2_clock += max(ts.values())
        sched.end_round()
    return sfl_clock, s2_clock


def run():
    # fig 5: x devices per round
    for x in (5, 10, 15, 20):
        with Timer() as t:
            sfl, s2 = _sim("vgg16", n_devices=100, per_round=x)
        emit(f"fig5.devices_{x}", t.us,
             f"sfl_clock={sfl:.1f};s2fl_clock={s2:.1f};"
             f"speedup={sfl / s2:.2f}x")

    # fig 6: compositions
    for name, comp in (("5:3:2", {"high": 5, "mid": 3, "low": 2}),
                       ("2:3:5", {"high": 2, "mid": 3, "low": 5})):
        with Timer() as t:
            sfl, s2 = _sim("vgg16", n_devices=100, per_round=10,
                           composition=comp)
        emit(f"fig6.comp_{name}", t.us,
             f"sfl_clock={sfl:.1f};s2fl_clock={s2:.1f};"
             f"speedup={sfl / s2:.2f}x")

    # fig 7: |C| at 0.1 sampling
    for C in (20, 50, 100):
        with Timer() as t:
            sfl, s2 = _sim("vgg16", n_devices=C,
                           per_round=max(2, C // 10))
        emit(f"fig7.clientset_{C}", t.us,
             f"sfl_clock={sfl:.1f};s2fl_clock={s2:.1f};"
             f"speedup={sfl / s2:.2f}x")


if __name__ == "__main__":
    run()
