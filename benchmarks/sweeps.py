"""Figures 5/6/7 — configuration sweeps on the Eq.-1 simulated clock:
  fig5: number of participating devices x in {5, 10, 15, 20}
  fig6: device compositions High:Mid:Low = 5:3:2 vs 2:3:5, plus the
        sync vs semi_async vs phase-pipelined round-clock comparison on
        the straggler-heavy 2:3:5 mix (the pipelined timeline commits a
        group at the end of its server compute, so uploads/backwards/
        downloads of different devices overlap) and the finite-resource
        columns (contended ingress; full duplex contention + bounded
        server concurrency + re-dispatch gating) with the
        free-overlap <= contended <= resource-constrained clock
        ordering asserted
  fig7: client-set size |C| in {20, 50, 100} at fixed 0.1 sampling

The time/straggler effects are what Eq. 1 defines, so these sweeps report
the simulated per-round wall clock of SFL vs S²FL through the shared
``RoundDriver`` (analytic channel-byte costs; the accuracy curves of the
figures are covered by benchmarks/accuracy.py at reduced scale)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, emit


def _sim(arch, n_devices, per_round, composition=None, rounds=20, seed=0,
         variants=({"mode": "sync"},)):
    """One SFL baseline plus one S²FL driver per variant dict
    (exec mode / staleness cap / pipeline / resource knobs), all driven
    over the SAME participant draw — the model / split-cost /
    device-grid setup (the expensive part: XLA cost analysis per split)
    is built exactly once. Resource capacities ride on a per-variant
    CommChannel (``uplink``/``downlink`` elements/s) while
    ``server_slots``/``gate`` ride the driver. A variant with
    ``record: True`` gets a flight-level ``observe.Recorder`` injected
    so its clock can be critical-path-decomposed afterwards. Returns
    (sfl_clock, [s2_clock per variant], [recorder or None per
    variant])."""
    from repro.comm import CommChannel
    from repro.configs import get_config
    from repro.core.driver import AnalyticCost, RoundDriver
    from repro.core.scheduler import (FixedSplitScheduler,
                                      SlidingSplitScheduler)
    from repro.core.simulation import make_device_grid
    from repro.core.split import default_plan
    from repro.models import SplitModel
    from repro.observe import Recorder
    from repro.utils.flops import split_costs

    model = SplitModel(get_config(arch))
    plan = default_plan(model.n_units, k=3)
    costs = {s: split_costs(model, s) for s in plan.split_points}
    devices = make_device_grid(n_devices, seed=seed,
                               composition=composition)
    sfl = RoundDriver(FixedSplitScheduler(plan),
                      AnalyticCost(CommChannel(), costs, p=128), devices)
    s2s, recorders = [], []
    for v in variants:
        ch = CommChannel(uplink_capacity=v.get("uplink", 0.0),
                         downlink_capacity=v.get("downlink", 0.0))
        rec = Recorder() if v.get("record") else None
        recorders.append(rec)
        s2s.append(RoundDriver(
            SlidingSplitScheduler(plan), AnalyticCost(ch, costs, p=128),
            devices, mode=v.get("mode", "sync"),
            staleness_cap=v.get("staleness_cap", 1),
            pipeline=v.get("pipeline", False),
            server_concurrency=v.get("server_slots", 0),
            gate_redispatch=v.get("gate", False), recorder=rec))
    rng = np.random.default_rng(seed)
    for r in range(rounds):
        part = rng.choice(devices, size=per_round, replace=False)
        sfl.run_round(part)
        for drv in s2s:
            drv.run_round(part)
    # wait out in-flight semi_async stragglers and draining downloads so
    # every clock covers the same completed work (sync: empty heaps)
    for drv in s2s:
        drv.flush()
    return sfl.clock, [drv.clock for drv in s2s], recorders


def run(quick: bool = False):
    rounds = 6 if quick else 20
    n_dev = 30 if quick else 100

    # fig 5: x devices per round
    for x in ((5, 10) if quick else (5, 10, 15, 20)):
        with Timer() as t:
            sfl, (s2,), _ = _sim("vgg16", n_devices=n_dev, per_round=x,
                                 rounds=rounds)
        emit(f"fig5.devices_{x}", t.us,
             f"sfl_clock={sfl:.1f};s2fl_clock={s2:.1f};"
             f"speedup={sfl / s2:.2f}x")

    # fig 6: compositions, plus the execution modes on each mix —
    # semi_async closes the aggregation window at the quorum arrival
    # instead of the Eq.-1 max() barrier, and the phase pipeline commits
    # at server-compute completion (uploads/downloads overlap), so on
    # the straggler-heavy 2:3:5 grid the ordering
    # pipelined <= phase-sequential <= sync must hold. Two resource
    # columns price the pipeline against a FINITE Main Server: `cont`
    # contends the shared ingress only (uplink capacity = one Table-1
    # server link shared by the cohort, in-flight uploads carried
    # across windows), `rsrc` additionally contends the egress, bounds
    # the GPU to 2 concurrent group backwards, and gates re-dispatch on
    # the device's own draining download — so the wall-clock ordering
    # free-overlap <= contended <= resource-constrained must hold.
    from repro.core.simulation import SERVER_RATE
    for name, comp in (("5:3:2", {"high": 5, "mid": 3, "low": 2}),
                       ("2:3:5", {"high": 2, "mid": 3, "low": 5})):
        with Timer() as t:
            sfl, (s2, s2_async, s2_pipe, s2_cont, s2_rsrc), recs = _sim(
                "vgg16", n_devices=n_dev, per_round=10,
                composition=comp, rounds=rounds,
                variants=({"mode": "sync"},
                          {"mode": "semi_async"},
                          {"mode": "semi_async", "pipeline": True},
                          {"mode": "semi_async", "pipeline": True,
                           "uplink": SERVER_RATE},
                          {"mode": "semi_async", "pipeline": True,
                           "uplink": SERVER_RATE,
                           "downlink": SERVER_RATE,
                           "server_slots": 2, "gate": True,
                           "record": True}))
        async_speedup = s2 / s2_async
        pipe_speedup = s2_async / s2_pipe
        cont_slowdown = s2_cont / s2_pipe
        rsrc_slowdown = s2_rsrc / s2_pipe
        # critical-path attribution of the resource-constrained clock:
        # where its wall time actually went (fractions of the summed
        # window makespans), verified to reconstruct each window
        from repro.observe import summarize, verify_reconstruction
        verify_reconstruction(recs[-1])
        crit = summarize(recs[-1])
        fr = crit["fractions"]
        emit(f"fig6.comp_{name}", t.us,
             f"sfl_clock={sfl:.1f};s2fl_clock={s2:.1f};"
             f"speedup={sfl / s2:.2f}x;"
             f"s2fl_async_clock={s2_async:.1f};"
             f"async_vs_sync={async_speedup:.2f}x;"
             f"s2fl_pipe_clock={s2_pipe:.1f};"
             f"pipe_vs_seq={pipe_speedup:.2f}x;"
             f"s2fl_pipe_cont_clock={s2_cont:.1f};"
             f"contention_slowdown={cont_slowdown:.2f}x;"
             f"s2fl_pipe_rsrc_clock={s2_rsrc:.1f};"
             f"resource_slowdown={rsrc_slowdown:.2f}x;"
             f"crit_uplink_wait={fr.get('uplink_wait', 0.0):.3f};"
             f"crit_queue_wait={fr.get('queue_wait', 0.0):.3f};"
             f"crit_server={fr.get('server_compute', 0.0):.3f};"
             f"crit_downlink={fr.get('downlink_drain', 0.0):.3f};"
             f"top_straggler={crit['top_straggler']}")
        if name == "2:3:5":
            # acceptance: straggler overlap can only help the clock, and
            # phase overlap can only help further:
            # pipelined <= phase-sequential <= sync
            assert async_speedup >= 1.0, (s2, s2_async)
            assert pipe_speedup >= 1.0, (s2_async, s2_pipe)
        # acceptance (both mixes): finite resources can only slow the
        # pipelined clock — resource-constrained >= pipelined(contended)
        # >= free-overlap. The exact theorem is property-tested under a
        # FixedSplitScheduler (tests/test_driver_properties.py); the
        # sliding scheduler here adapts to the stretched times it
        # observes, so allow it a small legitimate mitigation margin.
        assert cont_slowdown >= 0.98, (s2_cont, s2_pipe)
        assert rsrc_slowdown >= cont_slowdown * 0.98, (s2_rsrc, s2_cont)

    # fig 7: |C| at 0.1 sampling
    for C in ((20,) if quick else (20, 50, 100)):
        with Timer() as t:
            sfl, (s2,), _ = _sim("vgg16", n_devices=C,
                                 per_round=max(2, C // 10), rounds=rounds)
        emit(f"fig7.clientset_{C}", t.us,
             f"sfl_clock={sfl:.1f};s2fl_clock={s2:.1f};"
             f"speedup={sfl / s2:.2f}x")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny-scale smoke (CI)")
    run(quick=ap.parse_args().quick)
